"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import am as am_lib
from repro.core.encoding import binarize_query
from repro.core.imc import (
    ImcArrayConfig, map_basic, map_memhd, map_partitioned,
)
from repro.core.init import confusion_matrix, misprediction_counts
from repro.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def bipolar_matrix(draw, max_rows=24, max_cols=96):
    r = draw(st.integers(1, max_rows))
    c = draw(st.integers(8, max_cols).filter(lambda x: x % 8 == 0))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.choice([-1.0, 1.0], size=(r, c)))


class TestBipolarRankEquivalence:
    """{0,1} vs {-1,+1} encodings give identical argmax rankings.

    dot(q, 2b-1) = 2*dot(q, b) - sum(q): affine in the {0,1} similarity
    with a per-query constant, so rankings over centroids are preserved —
    this is what licenses storing the paper's {0,1} cells as MXU-friendly
    +-1 operands (DESIGN.md §2).
    """

    @settings(**SETTINGS)
    @given(bipolar_matrix(), st.integers(0, 2**31 - 1))
    def test_rank_preserved(self, am_bipolar, seed):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.choice([-1.0, 1.0],
                                   size=(4, am_bipolar.shape[1])))
        uni = (am_bipolar + 1.0) / 2.0  # {0, 1}
        sims_bi = q @ am_bipolar.T
        sims_uni = q @ uni.T
        np.testing.assert_array_equal(
            np.asarray(jnp.argmax(sims_bi, -1)),
            np.asarray(jnp.argmax(sims_uni, -1)))


class TestBinarization:
    @settings(**SETTINGS)
    @given(st.integers(0, 2**31 - 1), st.integers(2, 20), st.integers(8, 64))
    def test_idempotent(self, seed, r, c):
        rng = np.random.default_rng(seed)
        fp = jnp.asarray(rng.normal(size=(r, c)))
        b1 = am_lib.binarize_am(fp)
        b2 = am_lib.binarize_am(b1)
        # Binarizing a bipolar matrix keeps it bipolar with same signs
        # (mean of +-1 values lies strictly between -1 and 1 unless
        # degenerate all-equal case).
        if float(jnp.abs(b1).sum()) != b1.size:  # pragma: no cover
            return
        if float(jnp.abs(jnp.mean(b1))) < 1.0 - 1e-6:
            np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))

    @settings(**SETTINGS)
    @given(st.integers(0, 2**31 - 1))
    def test_unipolar_roundtrip(self, seed):
        rng = np.random.default_rng(seed)
        fp = jnp.asarray(rng.normal(size=(8, 32)))
        b = am_lib.binarize_am(fp)
        np.testing.assert_array_equal(
            np.asarray(am_lib.from_unipolar(am_lib.to_unipolar(b))),
            np.asarray(b))

    @settings(**SETTINGS)
    @given(st.integers(0, 2**31 - 1))
    def test_threshold_is_mean(self, seed):
        rng = np.random.default_rng(seed)
        fp = jnp.asarray(rng.normal(size=(6, 40)).astype(np.float32))
        b = am_lib.binarize_am(fp, "mean")
        mu = float(jnp.mean(fp))
        want = np.where(np.asarray(fp) > mu, 1.0, -1.0)
        np.testing.assert_array_equal(np.asarray(b), want)


class TestPackBitsProperty:
    @settings(**SETTINGS)
    @given(bipolar_matrix())
    def test_roundtrip(self, x):
        np.testing.assert_array_equal(
            np.asarray(ref.unpack_bits(ref.pack_bits(x))), np.asarray(x))


class TestQueryBinarization:
    @settings(**SETTINGS)
    @given(st.integers(0, 2**31 - 1))
    def test_strictly_bipolar(self, seed):
        rng = np.random.default_rng(seed)
        h = jnp.asarray(rng.normal(size=(5, 64)))
        q = binarize_query(h)
        assert set(np.unique(np.asarray(q))) <= {-1.0, 1.0}
        # zero maps to +1 (no third value)
        q0 = binarize_query(jnp.zeros((2, 8)))
        assert float(q0.min()) == 1.0


class TestConfusion:
    @settings(**SETTINGS)
    @given(st.integers(0, 2**31 - 1), st.integers(2, 8), st.integers(5, 60))
    def test_counts_sum(self, seed, k, n):
        rng = np.random.default_rng(seed)
        true = jnp.asarray(rng.integers(0, k, size=(n,)))
        pred = jnp.asarray(rng.integers(0, k, size=(n,)))
        conf = confusion_matrix(pred, true, k)
        assert int(jnp.sum(conf)) == n
        mis = misprediction_counts(conf)
        assert int(jnp.sum(mis)) == int(jnp.sum(pred != true))
        assert np.all(np.asarray(mis) >= 0)


class TestImcMappingInvariants:
    """Closed-form invariants of the core/imc.py cost model."""

    @settings(**SETTINGS)
    @given(st.integers(1, 4096), st.integers(1, 2048),
           st.sampled_from([32, 64, 128, 256]),
           st.sampled_from([32, 64, 128, 256]))
    def test_utilization_never_exceeds_one(self, rows, cols, ar, ac):
        arr = ImcArrayConfig(rows=ar, cols=ac)
        c = map_basic(rows, cols, arr)
        assert 0.0 < c.utilization <= 1.0

    @settings(**SETTINGS)
    @given(st.integers(1, 4), st.integers(1, 6), st.integers(1, 300),
           st.sampled_from([32, 64, 128]))
    def test_partitioning_saves_arrays_never_cycles(self, m, p, cols, a):
        # The paper's Fig. 1-(b) point: with segment rows tiling the
        # array exactly (rows = m*P*A), partitioning keeps the cycle
        # count of the basic mapping and needs at most as many arrays.
        arr = ImcArrayConfig(rows=a, cols=a)
        rows = m * p * a
        basic = map_basic(rows, cols, arr)
        part = map_partitioned(rows, cols, p, arr)
        assert part.cycles == basic.cycles   # never saves cycles...
        assert part.arrays <= basic.arrays   # ...but saves arrays
        assert part.utilization >= basic.utilization - 1e-12

    @settings(**SETTINGS)
    @given(st.sampled_from([32, 64, 128, 256, 512]))
    def test_memhd_array_sized_am_is_one_shot(self, a):
        arr = ImcArrayConfig(rows=a, cols=a)
        c = map_memhd(a, a, arr)
        assert c.cycles == 1 and c.arrays == 1
        assert c.utilization == 1.0

    @settings(**SETTINGS)
    @given(st.integers(1, 2048), st.integers(1, 512),
           st.integers(1, 2048), st.integers(1, 512))
    def test_energy_monotone_in_tiles(self, r1, c1, r2, c2):
        arr = ImcArrayConfig()
        m1, m2 = map_basic(r1, c1, arr), map_basic(r2, c2, arr)
        assert (m1.cycles <= m2.cycles) == \
            (m1.energy_pj(arr) <= m2.energy_pj(arr))
        assert m1.energy_pj(arr) == m1.cycles * arr.e_read_pass_pj


class TestClassMaxSims:
    @settings(**SETTINGS)
    @given(st.integers(0, 2**31 - 1), st.integers(2, 6), st.integers(8, 30))
    def test_matches_loop(self, seed, k, c):
        rng = np.random.default_rng(seed)
        sims = jnp.asarray(rng.normal(size=(3, c)).astype(np.float32))
        owners = jnp.asarray(
            np.concatenate([np.arange(k),
                            rng.integers(0, k, size=(c - k,))]),
            dtype=jnp.int32)
        got = np.asarray(am_lib.class_max_sims(sims, owners, k))
        for b in range(3):
            for cls in range(k):
                mask = np.asarray(owners) == cls
                want = np.asarray(sims)[b][mask].max()
                np.testing.assert_allclose(got[b, cls], want, rtol=1e-6)
