"""Online serving engine: deadline-aware batching policy, streaming
QAIL folds (drift recovery + live class append on packed AND
hierarchical backends under ShardedArtifact), atomic generation swaps
(pre-swap futures bit-exact on the old artifact), generation
metrics/events, and the zero-steady-state-recompile contract across
shape-stable swaps."""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro import obs
from repro.deploy import ShardedArtifact
from repro.serve import (
    Arrival, Feedback, OnlineEngine, OnlineRequest, ServiceModel,
    StreamingUpdater, apply_drift, batch_buckets, feedback_burst,
    merge_events, plan_batch, poisson_arrivals,
)


@pytest.fixture(scope="module")
def ds():
    from repro.data import load_dataset
    return load_dataset("mnist", train_per_class=80, test_per_class=30)


@pytest.fixture(scope="module")
def full_model(ds):
    """Trained on every class — the drift-recovery scenarios."""
    from repro.core import EncoderConfig, MemhdConfig, MemhdModel
    enc = EncoderConfig(kind="projection", features=ds.features, dim=256)
    amc = MemhdConfig(dim=256, columns=3 * ds.classes, classes=ds.classes,
                      epochs=3, kmeans_iters=3)
    m = MemhdModel.create(jax.random.key(0), enc, amc)
    m, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)
    return m


@pytest.fixture(scope="module")
def partial_model(ds):
    """Trained WITHOUT the last class — the live-append scenarios."""
    from repro.core import EncoderConfig, MemhdConfig, MemhdModel
    known = ds.classes - 1
    mask = np.asarray(ds.train_y) < known
    enc = EncoderConfig(kind="projection", features=ds.features, dim=256)
    amc = MemhdConfig(dim=256, columns=3 * known, classes=known,
                      epochs=3, kmeans_iters=3)
    m = MemhdModel.create(jax.random.key(0), enc, amc)
    m, _ = m.fit(jax.random.key(1), np.asarray(ds.train_x)[mask],
                 np.asarray(ds.train_y)[mask])
    return m, known


def _est(_rows):
    return 0.003


def _req(rid, rows=4, t=0.0, deadline_ms=None, f=6):
    return OnlineRequest(rid=rid, feats=np.zeros((rows, f), np.float32),
                         t_arrival=t, deadline_ms=deadline_ms)


class TestPlanBatch:
    """The admission policy, as pure unit checks."""

    def test_empty_queue_waits(self):
        assert plan_batch([], 0.0, max_batch=16,
                          estimate_rows_s=_est) == 0

    def test_full_batch_closes(self):
        q = [_req(i, rows=8) for i in range(3)]
        assert plan_batch(q, 0.0, max_batch=16,
                          estimate_rows_s=_est) == 2

    def test_underfull_best_effort_waits(self):
        q = [_req(0, rows=4, t=0.0)]
        assert plan_batch(q, 0.001, max_batch=16, estimate_rows_s=_est,
                          max_wait_s=0.05) == 0

    def test_max_wait_closes(self):
        q = [_req(0, rows=4, t=0.0)]
        assert plan_batch(q, 0.06, max_batch=16, estimate_rows_s=_est,
                          max_wait_s=0.05) == 1

    def test_tight_deadline_closes(self):
        # Deadline 10ms, service estimate 3ms, margin 2ms: at t=6ms the
        # slack (10 - 6 - 3 = 1ms) is under the margin -> close now.
        q = [_req(0, rows=4, t=0.0, deadline_ms=10.0)]
        assert plan_batch(q, 0.006, max_batch=16, estimate_rows_s=_est,
                          margin_s=0.002, max_wait_s=1.0) == 1

    def test_loose_deadline_waits(self):
        q = [_req(0, rows=4, t=0.0, deadline_ms=500.0)]
        assert plan_batch(q, 0.006, max_batch=16, estimate_rows_s=_est,
                          margin_s=0.002, max_wait_s=1.0) == 0

    def test_inflight_eta_tightens_slack(self):
        # Same instant as the loose case, but 490ms of queued-up
        # in-flight work ahead of us eats the entire budget.
        q = [_req(0, rows=4, t=0.0, deadline_ms=500.0)]
        assert plan_batch(q, 0.006, max_batch=16, estimate_rows_s=_est,
                          inflight_eta_s=0.49, margin_s=0.002,
                          max_wait_s=1.0) == 1

    def test_flush_closes_any_nonempty(self):
        q = [_req(0, rows=1)]
        assert plan_batch(q, 0.0, max_batch=16, estimate_rows_s=_est,
                          flush=True) == 1

    def test_never_splits_requests(self):
        # 10 + 10 rows into max_batch 16: only the head request closes.
        q = [_req(0, rows=10), _req(1, rows=10)]
        assert plan_batch(q, 0.0, max_batch=16, estimate_rows_s=_est,
                          flush=True) == 1


class TestBucketsAndServiceModel:
    def test_geometric_grid(self):
        assert batch_buckets(8, 64) == [8, 16, 32, 64]
        assert batch_buckets(8, 60) == [8, 16, 32, 64]
        assert batch_buckets(8, 8) == [8]

    def test_bad_args_raise(self):
        with pytest.raises(ValueError):
            batch_buckets(0, 64)

    def test_ewma_and_nearest_bucket_fallback(self):
        sm = ServiceModel(default_s=0.01, alpha=0.5)
        assert sm.estimate(16) == 0.01  # blind default
        sm.observe(16, 0.004)
        assert sm.estimate(16) == 0.004
        sm.observe(16, 0.008)
        assert sm.estimate(16) == pytest.approx(0.006)
        # An unseen larger bucket scales from the nearest known one.
        assert sm.estimate(32) == pytest.approx(0.012)


class TestStreamHelpers:
    def test_merge_orders_feedback_before_arrivals(self):
        a = Arrival(t=1.0, request=_req(0))
        f = Feedback(t=1.0, feats=np.zeros((1, 6), np.float32),
                     labels=np.zeros(1, np.int64))
        assert merge_events([a], [f]) == [f, a]

    def test_poisson_class_filter(self, ds):
        te_y = np.asarray(ds.test_y)
        evs = poisson_arrivals(np.asarray(ds.test_x), n_requests=20,
                               rate_qps=100, labels_pool=te_y,
                               classes=[0, 1], seed=3)
        assert len(evs) == 20
        for ev in evs:
            assert set(np.unique(ev.request.labels)) <= {0, 1}
        # times strictly increase and deadlines default to None
        ts = [ev.t for ev in evs]
        assert ts == sorted(ts)
        assert evs[0].request.t_deadline is None

    def test_feedback_burst_chunks_fold_on_last(self):
        x = np.zeros((10, 6), np.float32)
        y = np.arange(10)
        evs = feedback_burst(x, y, t=2.0, chunk=4, fold=True)
        assert [e.feats.shape[0] for e in evs] == [4, 4, 2]
        assert [e.fold for e in evs] == [False, False, True]

    def test_apply_drift_bounds(self):
        x = np.random.default_rng(0).normal(size=(4, 9)).astype(np.float32)
        np.testing.assert_allclose(apply_drift(x, 0.0), x)
        assert apply_drift(x, 0.5).dtype == np.float32
        with pytest.raises(ValueError):
            apply_drift(x, 1.5)


class TestStreamingUpdater:
    def test_fold_empty_buffer_returns_none(self, full_model):
        upd = StreamingUpdater(full_model,
                               full_model.deploy(target="packed"))
        assert upd.fold() is None
        assert upd.generation == 0

    def test_buffer_cap_drops_oldest(self, full_model):
        upd = StreamingUpdater(full_model,
                               full_model.deploy(target="packed"),
                               buffer_cap=10)
        x = np.zeros((6, 4), np.float32)
        upd.ingest(x, np.zeros(6))
        upd.ingest(x + 1, np.ones(6))
        assert upd.buffered == 6  # first chunk evicted whole
        upd.ingest(np.zeros((25, 4), np.float32), np.zeros(25))
        assert upd.buffered == 10  # single oversized chunk truncated

    def test_should_fold_policy(self, full_model):
        upd = StreamingUpdater(full_model,
                               full_model.deploy(target="packed"),
                               fold_every=8)
        upd.ingest(np.zeros((5, 4), np.float32), np.zeros(5))
        assert not upd.should_fold
        upd.ingest(np.zeros((5, 4), np.float32), np.zeros(5))
        assert upd.should_fold

    def test_drifted_fold_recovers_accuracy(self, ds, full_model):
        """The headline streaming claim: labeled drifted feedback folded
        through QAIL recovers accuracy on the drifted distribution, and
        the same-geometry swap is shape-stable."""
        dep = full_model.deploy(target="packed")
        tx, ty = np.asarray(ds.test_x), np.asarray(ds.test_y)
        dx = apply_drift(tx, 0.5)
        acc_before = np.mean(np.asarray(dep.predict(dx)) == ty)
        upd = StreamingUpdater(full_model, dep, fold_epochs=3)
        upd.ingest(apply_drift(np.asarray(ds.train_x), 0.5),
                   np.asarray(ds.train_y))
        result = upd.fold()
        acc_after = np.mean(np.asarray(upd.artifact.predict(dx)) == ty)
        assert result.shape_stable
        assert result.n_new_classes == 0
        assert result.generation == 1 and upd.generation == 1
        assert 0.0 <= result.miss_rate <= 1.0
        assert acc_after >= acc_before + 0.05, (acc_before, acc_after)
        # Shape-stable swap: serving the new artifact at an
        # already-warm batch shape compiles nothing.
        warm = dx[:32]
        jax.block_until_ready(upd.artifact.predict(warm))
        upd.ingest(apply_drift(np.asarray(ds.train_x), 0.5),
                   np.asarray(ds.train_y))
        assert upd.fold().shape_stable
        with obs.assert_no_recompiles("post-swap warm-shape predict"):
            jax.block_until_ready(upd.artifact.predict(warm))


class TestGenerationObservability:
    def test_gauge_histogram_and_event_log(self, ds, full_model,
                                           tmp_path):
        path = tmp_path / "events.jsonl"
        upd = StreamingUpdater(full_model,
                               full_model.deploy(target="packed"),
                               events=obs.EventLog(str(path)))
        before = obs.REGISTRY.get("update_fold_ms")
        n_before = sum(v["count"] for _, v in before.series()) \
            if before is not None else 0
        upd.ingest(np.asarray(ds.train_x)[:32],
                   np.asarray(ds.train_y)[:32])
        result = upd.fold()
        assert obs.gauge("model_generation").value() == 1.0
        hist = obs.REGISTRY.get("update_fold_ms")
        assert sum(v["count"] for _, v in hist.series()) == n_before + 1
        lines = [json.loads(line) for line
                 in path.read_text().splitlines()]
        folds = [rec for rec in lines if rec["event"] == "model_fold"]
        assert len(folds) == 1
        assert folds[0]["generation"] == 1
        assert folds[0]["n_samples"] == 32
        assert folds[0]["shape_stable"] is True
        assert folds[0]["fold_ms"] == pytest.approx(result.fold_ms,
                                                    abs=0.01)


class TestClassAppend:
    """Acceptance: a class never seen at training time is appended
    mid-serving — on the packed AND hierarchical backends, under the
    multi-device ShardedArtifact wrapper — and the swap is atomic."""

    @pytest.mark.parametrize("target", ["packed", "hierarchical"])
    def test_append_new_class_sharded(self, ds, partial_model, target):
        model, known = partial_model
        dep = ShardedArtifact(model.deploy(target=target), devices=1)
        upd = StreamingUpdater(model, dep, fold_epochs=3)
        tr_x, tr_y = np.asarray(ds.train_x), np.asarray(ds.train_y)
        te_x, te_y = np.asarray(ds.test_x), np.asarray(ds.test_y)
        new_test = te_x[te_y == known]
        # Before: the held-out class cannot be predicted (label space
        # ends at known-1).
        assert np.asarray(dep.predict(new_test)).max() < known
        new = tr_y == known
        upd.ingest(tr_x[new], tr_y[new])
        result = upd.fold()
        assert result.n_new_classes == 1
        assert not result.shape_stable  # (D,C) grew -> re-deploy
        assert upd.model.am_cfg.classes == known + 1
        assert isinstance(upd.artifact, ShardedArtifact)
        # jit caches survive the swap: the wrapper shares its _fns table
        assert upd.artifact._fns is dep._fns
        preds = np.asarray(upd.artifact.predict(new_test))
        frac_new = np.mean(preds == known)
        assert frac_new >= 0.5, frac_new
        # Old classes keep working (no catastrophic forgetting from one
        # append fold).
        old_test = te_x[te_y < known]
        acc_old = np.mean(np.asarray(upd.artifact.predict(old_test))
                          == te_y[te_y < known])
        assert acc_old >= 0.3, acc_old

    def test_preswap_inflight_bit_exact(self, ds, partial_model):
        """A future dispatched against generation N must resolve to
        generation-N results even when the swap to N+1 lands before the
        host looks at it — the artifact is an immutable jit operand."""
        model, known = partial_model
        dep = ShardedArtifact(model.deploy(target="packed"), devices=1)
        upd = StreamingUpdater(model, dep, fold_epochs=1)
        te_x = np.asarray(ds.test_x)[:48]
        want_old = np.asarray(dep.predict(te_x))  # warm + reference
        old_artifact = upd.artifact
        fut = old_artifact.predict(te_x)  # in flight across the swap
        tr_y = np.asarray(ds.train_y)
        new = tr_y == known
        upd.ingest(np.asarray(ds.train_x)[new], tr_y[new])
        upd.fold()
        assert upd.artifact is not old_artifact  # replaced, not mutated
        np.testing.assert_array_equal(np.asarray(fut), want_old)
        # And the old generation still answers identically post-swap.
        np.testing.assert_array_equal(
            np.asarray(old_artifact.predict(te_x)), want_old)


class TestOnlineEngine:
    def _engine(self, model, target="packed", **kw):
        dep = model.deploy(target=target)
        upd = StreamingUpdater(model, dep, fold_epochs=1)
        kw.setdefault("max_batch", 32)
        kw.setdefault("max_wait_ms", 5.0)
        return OnlineEngine(upd, **kw)

    def test_empty_stream(self, full_model):
        eng = self._engine(full_model)
        report = eng.serve([])
        assert report["requests"] == 0
        assert report["pad_overhead"] is None
        assert report["lat_ms_p50"] is None
        assert report["recompiles_steady_state"] == 0

    def test_oversized_request_rejected(self, full_model):
        eng = self._engine(full_model, max_batch=16)
        big = OnlineRequest(rid=0,
                            feats=np.zeros((17, 64), np.float32))
        with pytest.raises(ValueError, match="max_batch"):
            eng.serve([Arrival(t=0.0, request=big)])

    def test_stream_serves_every_request_bit_exact(self, ds,
                                                   full_model):
        eng = self._engine(full_model, depth=2)
        evs = poisson_arrivals(np.asarray(ds.test_x), n_requests=30,
                               rate_qps=3000, max_size=6,
                               labels_pool=np.asarray(ds.test_y),
                               seed=7)
        report = eng.serve(evs)
        assert report["requests"] == 30
        assert report["recompiles_steady_state"] == 0
        assert report["rows"] == sum(e.request.size for e in evs)
        assert report["rows_padded"] % eng.tile == 0
        dep = eng.artifact
        for ev in evs:
            np.testing.assert_array_equal(
                eng.responses[ev.request.rid],
                np.asarray(dep.predict(ev.request.feats)))

    def test_shape_stable_swap_zero_recompiles(self, ds, full_model):
        """Tentpole contract: a mid-stream drift fold swaps the model
        with ZERO steady-state recompiles — every compile in the run
        sits inside the warmup/fold windows and the rewarm window is
        never entered."""
        eng = self._engine(full_model, depth=2)
        tx, ty = np.asarray(ds.test_x), np.asarray(ds.test_y)
        ev1 = poisson_arrivals(tx, n_requests=20, rate_qps=3000,
                               max_size=6, labels_pool=ty, seed=8)
        t = ev1[-1].t + 1e-3
        fb = feedback_burst(apply_drift(np.asarray(ds.train_x), 0.4),
                            np.asarray(ds.train_y), t=t, fold=True)
        ev2 = poisson_arrivals(apply_drift(tx, 0.4), n_requests=20,
                               rate_qps=3000, max_size=6,
                               labels_pool=ty, start=t, rid_base=1000,
                               seed=9)
        report = eng.serve(merge_events(ev1, fb, ev2))
        assert report["requests"] == 40
        assert report["model_generation"] == 1
        gen = report["generations"][0]
        assert gen["shape_stable"] is True
        assert gen["steady_recompiles_before_swap"] == 0
        assert report["recompiles_steady_state"] == 0
        assert report["recompiles_excluded"]["rewarm"] == 0
        json.dumps(report)  # report stays a JSON document

    def test_mid_stream_class_append(self, ds, partial_model):
        """Acceptance: the engine appends a never-seen class live and
        post-swap requests predict it; the growth recompiles land in
        the excluded fold/rewarm windows, steady state stays at zero."""
        model, known = partial_model
        eng = self._engine(model, depth=2)
        tx, ty = np.asarray(ds.test_x), np.asarray(ds.test_y)
        ev1 = poisson_arrivals(tx, n_requests=16, rate_qps=3000,
                               max_size=6, labels_pool=ty,
                               classes=range(known), seed=10)
        t = ev1[-1].t + 1e-3
        tr_y = np.asarray(ds.train_y)
        new = tr_y == known
        fb = feedback_burst(np.asarray(ds.train_x)[new], tr_y[new],
                            t=t, fold=True)
        ev2 = poisson_arrivals(tx, n_requests=16, rate_qps=3000,
                               max_size=6, labels_pool=ty,
                               classes=[known], start=t, rid_base=1000,
                               seed=11)
        report = eng.serve(merge_events(ev1, fb, ev2))
        assert report["model_generation"] == 1
        gen = report["generations"][0]
        assert gen["shape_stable"] is False
        assert gen["n_new_classes"] == 1
        assert gen["classes"] == known + 1
        assert report["recompiles_steady_state"] == 0
        assert report["recompiles_excluded"]["rewarm"] > 0
        hits = total = 0
        for ev in ev2:
            pred = np.asarray(eng.responses[ev.request.rid])
            hits += int((pred == known).sum())
            total += pred.shape[0]
        assert hits / total >= 0.5, (hits, total)
