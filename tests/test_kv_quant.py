"""int8 KV-cache decode: numerics vs the f32 path + size accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import AttnSpec


class TestQuantRows:
    def test_roundtrip_error_bounded(self):
        x = jax.random.normal(jax.random.key(0), (4, 8, 16)) * 3.0
        q, s = L._quant_rows(x)
        deq = q.astype(jnp.float32) * s.astype(jnp.float32)[..., None]
        rel = float(jnp.max(jnp.abs(deq - x)) / jnp.max(jnp.abs(x)))
        assert rel < 0.01, rel
        assert q.dtype == jnp.int8 and s.dtype == jnp.float16

    def test_zero_rows_safe(self):
        q, s = L._quant_rows(jnp.zeros((2, 3, 8)))
        assert np.all(np.asarray(q) == 0)
        assert np.all(np.isfinite(np.asarray(s, dtype=np.float32)))


class TestQuantDecode:
    @pytest.mark.parametrize("arch", ["qwen1.5-32b", "granite-20b"])
    def test_matches_forward_within_quant_error(self, arch):
        cfg = get_smoke_config(arch)
        cfgq = dataclasses.replace(cfg, kv_cache_quant=True)
        B, S = 2, 64
        params, _ = T.init_params(jax.random.key(0), cfg)
        tokens = jax.random.randint(jax.random.key(1), (B, S), 0,
                                    cfg.vocab_size)
        logits, _ = jax.jit(lambda p, b: T.forward(p, cfg, b))(
            params, {"tokens": tokens, "targets": tokens})
        caches = T.init_cache(cfgq, B, S)
        step = jax.jit(lambda p, b, c: T.decode_step(p, cfgq, b, c))
        for t in range(S):
            lg, caches = step(params, {"tokens": tokens[:, t:t + 1]},
                              caches)
        diff = float(jnp.max(jnp.abs(lg - logits[:, -1])))
        scale = float(jnp.max(jnp.abs(logits[:, -1]))) + 1e-6
        assert diff < 5e-2 * scale, (arch, diff / scale)

    def test_cache_bytes_halved(self):
        spec = AttnSpec(kind="gqa", n_heads=8, n_kv_heads=8, head_dim=64)

        def nbytes(tree):
            return sum(x.size * x.dtype.itemsize
                       for x in jax.tree.leaves(tree))

        full = L.init_gqa_cache(spec, 4, 1024, jnp.bfloat16)
        quant = L.init_gqa_cache(spec, 4, 1024, jnp.bfloat16, quant=True)
        ratio = nbytes(full) / nbytes(quant)
        assert ratio > 1.8, ratio  # ~2x minus the fp16 scales
