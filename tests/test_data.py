"""Data pipeline: determinism, resume, dataset structure."""
import os

import numpy as np

from repro.core.types import dataset_spec
from repro.data import load_dataset
from repro.data.lm import LmDataConfig, PipelineState, next_batch


class TestHdcDatasets:
    def test_shapes_faithful(self):
        for name in ("mnist", "fmnist", "isolet"):
            spec = dataset_spec(name)
            ds = load_dataset(name, train_per_class=20, test_per_class=5)
            assert ds.train_x.shape == (20 * spec.classes, spec.features)
            assert ds.test_x.shape == (5 * spec.classes, spec.features)
            assert float(ds.train_x.min()) >= 0.0
            assert float(ds.train_x.max()) <= 1.0
            assert ds.source == "synthetic"

    def test_deterministic(self):
        a = load_dataset("mnist", seed=3, train_per_class=10,
                         test_per_class=5)
        b = load_dataset("mnist", seed=3, train_per_class=10,
                         test_per_class=5)
        np.testing.assert_array_equal(np.asarray(a.train_x),
                                      np.asarray(b.train_x))

    def test_class_balance(self):
        ds = load_dataset("isolet", train_per_class=12, test_per_class=4)
        y = np.asarray(ds.train_y)
        counts = np.bincount(y, minlength=26)
        assert np.all(counts == 12)


class TestRealDataLoader:
    """The $MEMHD_DATA_DIR/<name>.npz branch of load_dataset.

    Only the synthetic fallback was exercised before; these write a tmp
    real-data fixture and assert the real path, its ``source`` tagging,
    and the per-class subsampling applied on top of real data.
    """

    CLASSES = dataset_spec("mnist").classes

    def _write_npz(self, root, name="mnist", per_class_train=6,
                   per_class_test=4, features=12, seed=0):
        rng = np.random.default_rng(seed)

        def split(n_pc):
            x = rng.random((n_pc * self.CLASSES, features),
                           dtype=np.float32)
            y = np.repeat(np.arange(self.CLASSES, dtype=np.int32), n_pc)
            return x, y

        train_x, train_y = split(per_class_train)
        test_x, test_y = split(per_class_test)
        np.savez(os.path.join(root, f"{name}.npz"),
                 train_x=train_x, train_y=train_y,
                 test_x=test_x, test_y=test_y)
        return train_x, train_y, test_x, test_y

    def test_real_path_and_source_tagging(self, tmp_path, monkeypatch):
        monkeypatch.setenv("MEMHD_DATA_DIR", str(tmp_path))
        train_x, train_y, test_x, test_y = self._write_npz(tmp_path)
        ds = load_dataset("mnist")
        assert ds.source == "real" and ds.name == "mnist"
        np.testing.assert_array_equal(np.asarray(ds.train_x), train_x)
        np.testing.assert_array_equal(np.asarray(ds.train_y), train_y)
        np.testing.assert_array_equal(np.asarray(ds.test_x), test_x)
        np.testing.assert_array_equal(np.asarray(ds.test_y), test_y)
        assert ds.features == train_x.shape[1]

    def test_real_per_class_subsampling(self, tmp_path, monkeypatch):
        monkeypatch.setenv("MEMHD_DATA_DIR", str(tmp_path))
        self._write_npz(tmp_path)
        ds = load_dataset("mnist", train_per_class=3, test_per_class=2)
        assert ds.source == "real"
        train_counts = np.bincount(np.asarray(ds.train_y),
                                   minlength=self.CLASSES)
        test_counts = np.bincount(np.asarray(ds.test_y),
                                  minlength=self.CLASSES)
        assert np.all(train_counts == 3)
        assert np.all(test_counts == 2)

    def test_subsample_keeps_full_test_split_by_default(self, tmp_path,
                                                        monkeypatch):
        monkeypatch.setenv("MEMHD_DATA_DIR", str(tmp_path))
        self._write_npz(tmp_path, per_class_test=4)
        ds = load_dataset("mnist", train_per_class=2)  # no test_per_class
        assert ds.train_x.shape[0] == 2 * self.CLASSES
        assert ds.test_x.shape[0] == 4 * self.CLASSES

    def test_missing_file_falls_back_to_synthetic(self, tmp_path,
                                                  monkeypatch):
        monkeypatch.setenv("MEMHD_DATA_DIR", str(tmp_path))
        self._write_npz(tmp_path, name="mnist")
        ds = load_dataset("isolet", train_per_class=4, test_per_class=2)
        assert ds.source == "synthetic"
        # ...and the real file next to it still loads as real.
        assert load_dataset("mnist").source == "real"

    def test_unset_data_dir_synthesizes(self, monkeypatch):
        monkeypatch.delenv("MEMHD_DATA_DIR", raising=False)
        ds = load_dataset("mnist", train_per_class=2, test_per_class=1)
        assert ds.source == "synthetic"


class TestLmPipeline:
    def test_deterministic_and_stateful(self):
        cfg = LmDataConfig(vocab_size=1000, seq_len=64, global_batch=4)
        s0 = PipelineState(seed=7)
        b1, s1 = next_batch(cfg, s0)
        b2, s2 = next_batch(cfg, s1)
        # Same state -> same batch; different positions -> different data.
        b1r, _ = next_batch(cfg, PipelineState(seed=7, position=0))
        np.testing.assert_array_equal(b1["tokens"], b1r["tokens"])
        assert not np.array_equal(b1["tokens"], b2["tokens"])

    def test_resume_from_json(self):
        cfg = LmDataConfig(vocab_size=500, seq_len=32, global_batch=2)
        state = PipelineState(seed=1)
        for _ in range(3):
            _, state = next_batch(cfg, state)
        blob = state.to_json()
        resumed = PipelineState.from_json(blob)
        b_a, _ = next_batch(cfg, state)
        b_b, _ = next_batch(cfg, resumed)
        np.testing.assert_array_equal(b_a["tokens"], b_b["tokens"])

    def test_targets_are_shifted_tokens(self):
        cfg = LmDataConfig(vocab_size=100, seq_len=16, global_batch=2)
        b, _ = next_batch(cfg, PipelineState(seed=0))
        np.testing.assert_array_equal(b["tokens"][:, 1:],
                                      b["targets"][:, :-1])

    def test_token_range(self):
        cfg = LmDataConfig(vocab_size=777, seq_len=64, global_batch=2)
        b, _ = next_batch(cfg, PipelineState(seed=0))
        assert b["tokens"].min() >= 0
        assert b["tokens"].max() < 777


class TestPaperConfigs:
    def test_all_paper_points_construct(self):
        from repro.configs.memhd_paper import list_paper_points, paper_config
        n = 0
        for ds, g in list_paper_points():
            enc, am = paper_config(ds, g)
            assert enc.dim == am.dim
            assert am.columns >= am.classes
            n += 1
        assert n == 14  # 5 + 5 + 4 grid points

    def test_flagship_matches_table2(self):
        from repro.configs.memhd_paper import paper_config
        enc, am = paper_config("mnist")
        assert (am.dim, am.columns) == (128, 128)
        enc, am = paper_config("isolet")
        assert (am.dim, am.columns) == (512, 128)
        assert am.init_ratio == 1.0  # Fig. 6: ISOLET peaks at R=1.0

    def test_epochs_match_paper(self):
        from repro.configs.memhd_paper import paper_config
        _, am = paper_config("fmnist", "256x256")
        assert am.epochs == 100
