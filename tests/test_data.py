"""Data pipeline: determinism, resume, dataset structure."""
import numpy as np

from repro.core.types import dataset_spec
from repro.data import load_dataset
from repro.data.lm import LmDataConfig, PipelineState, next_batch


class TestHdcDatasets:
    def test_shapes_faithful(self):
        for name in ("mnist", "fmnist", "isolet"):
            spec = dataset_spec(name)
            ds = load_dataset(name, train_per_class=20, test_per_class=5)
            assert ds.train_x.shape == (20 * spec.classes, spec.features)
            assert ds.test_x.shape == (5 * spec.classes, spec.features)
            assert float(ds.train_x.min()) >= 0.0
            assert float(ds.train_x.max()) <= 1.0
            assert ds.source == "synthetic"

    def test_deterministic(self):
        a = load_dataset("mnist", seed=3, train_per_class=10,
                         test_per_class=5)
        b = load_dataset("mnist", seed=3, train_per_class=10,
                         test_per_class=5)
        np.testing.assert_array_equal(np.asarray(a.train_x),
                                      np.asarray(b.train_x))

    def test_class_balance(self):
        ds = load_dataset("isolet", train_per_class=12, test_per_class=4)
        y = np.asarray(ds.train_y)
        counts = np.bincount(y, minlength=26)
        assert np.all(counts == 12)


class TestLmPipeline:
    def test_deterministic_and_stateful(self):
        cfg = LmDataConfig(vocab_size=1000, seq_len=64, global_batch=4)
        s0 = PipelineState(seed=7)
        b1, s1 = next_batch(cfg, s0)
        b2, s2 = next_batch(cfg, s1)
        # Same state -> same batch; different positions -> different data.
        b1r, _ = next_batch(cfg, PipelineState(seed=7, position=0))
        np.testing.assert_array_equal(b1["tokens"], b1r["tokens"])
        assert not np.array_equal(b1["tokens"], b2["tokens"])

    def test_resume_from_json(self):
        cfg = LmDataConfig(vocab_size=500, seq_len=32, global_batch=2)
        state = PipelineState(seed=1)
        for _ in range(3):
            _, state = next_batch(cfg, state)
        blob = state.to_json()
        resumed = PipelineState.from_json(blob)
        b_a, _ = next_batch(cfg, state)
        b_b, _ = next_batch(cfg, resumed)
        np.testing.assert_array_equal(b_a["tokens"], b_b["tokens"])

    def test_targets_are_shifted_tokens(self):
        cfg = LmDataConfig(vocab_size=100, seq_len=16, global_batch=2)
        b, _ = next_batch(cfg, PipelineState(seed=0))
        np.testing.assert_array_equal(b["tokens"][:, 1:],
                                      b["targets"][:, :-1])

    def test_token_range(self):
        cfg = LmDataConfig(vocab_size=777, seq_len=64, global_batch=2)
        b, _ = next_batch(cfg, PipelineState(seed=0))
        assert b["tokens"].min() >= 0
        assert b["tokens"].max() < 777


class TestPaperConfigs:
    def test_all_paper_points_construct(self):
        from repro.configs.memhd_paper import list_paper_points, paper_config
        n = 0
        for ds, g in list_paper_points():
            enc, am = paper_config(ds, g)
            assert enc.dim == am.dim
            assert am.columns >= am.classes
            n += 1
        assert n == 14  # 5 + 5 + 4 grid points

    def test_flagship_matches_table2(self):
        from repro.configs.memhd_paper import paper_config
        enc, am = paper_config("mnist")
        assert (am.dim, am.columns) == (128, 128)
        enc, am = paper_config("isolet")
        assert (am.dim, am.columns) == (512, 128)
        assert am.init_ratio == 1.0  # Fig. 6: ISOLET peaks at R=1.0

    def test_epochs_match_paper(self):
        from repro.configs.memhd_paper import paper_config
        _, am = paper_config("fmnist", "256x256")
        assert am.epochs == 100
