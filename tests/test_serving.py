"""serve_memhd driver: batcher accounting, fused-vs-staged parity on
ragged request streams, the queue/service latency decomposition, the
obs integration (steady-state recompiles, dispatch tiers, trace
export), and the JSON report schema contract."""
import json

import jax
import numpy as np
import pytest

from repro import obs
from repro.launch.serve_memhd import (Request, build_report, make_batches,
                                      metrics_summary, serve_batches,
                                      synthetic_requests)


@pytest.fixture(scope="module")
def served(small_hdc_data):
    """A small trained model deployed packed (fused-servable)."""
    from repro.core import EncoderConfig, MemhdConfig, MemhdModel
    ds = small_hdc_data
    enc = EncoderConfig(kind="projection", features=ds.features, dim=128)
    amc = MemhdConfig(dim=128, columns=32, classes=ds.classes,
                      epochs=1, kmeans_iters=3)
    m = MemhdModel.create(jax.random.key(0), enc, amc)
    m, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)
    return ds, m, m.deploy(packed=True)


def _reqs(sizes, f=4):
    return [Request(rid=i, feats=np.zeros((n, f), np.float32))
            for i, n in enumerate(sizes)]


class TestBatcherAccounting:
    """Padding accounting of the greedy batcher, end to end."""

    def test_pad_accounting_exact(self, served):
        ds, _, dep = served
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=7,
                                  max_size=11, seed=5)
        _, stats = serve_batches(dep, reqs, max_batch=16, tile=8)
        sizes = [r.size for r in reqs]
        batches = make_batches(reqs, 16)
        want_padded = sum(-(-sum(r.size for r in b) // 8) * 8
                          for b in batches)
        assert stats["rows_real"] == sum(sizes)
        assert stats["rows_padded"] == want_padded
        assert stats["batches"] == len(batches)
        assert stats["pad_overhead"] == round(
            want_padded / sum(sizes) - 1, 3)
        assert stats["lat_ms_total"] >= 0

    def test_every_batch_tile_aligned(self, served):
        ds, _, dep = served
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=5,
                                  max_size=13, seed=2)
        _, stats = serve_batches(dep, reqs, max_batch=32, tile=8)
        assert stats["rows_padded"] % 8 == 0
        assert stats["rows_padded"] >= stats["rows_real"]

    def test_batcher_never_splits_requests(self):
        batches = make_batches(_reqs([5, 5, 5, 20, 3]), 12)
        flat = [r.rid for b in batches for r in b]
        assert sorted(flat) == [0, 1, 2, 3, 4]  # every request, once
        assert all(sum(r.size for r in b) <= 12
                   for b in batches if len(b) > 1)


class TestFusedServing:
    """--fused serving: single-dispatch pipeline, bit-exact with staged."""

    def test_fused_vs_staged_parity_on_ragged_stream(self, served):
        ds, _, dep = served
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=11,
                                  max_size=9, seed=7)
        staged, s_stats = serve_batches(dep, reqs, max_batch=24)
        fused, f_stats = serve_batches(dep, reqs, max_batch=24,
                                       fused=True)
        assert staged.keys() == fused.keys()
        for rid in staged:
            np.testing.assert_array_equal(staged[rid], fused[rid])
        # Identical batching either way — only the kernel path differs.
        assert s_stats["rows_padded"] == f_stats["rows_padded"]
        assert s_stats["batches"] == f_stats["batches"]

    def test_predict_features_matches_predict(self, served):
        ds, m, dep = served
        got = np.asarray(dep.predict_features(ds.test_x[:40]))
        np.testing.assert_array_equal(got,
                                      np.asarray(dep.predict(
                                          ds.test_x[:40])))
        np.testing.assert_array_equal(got,
                                      np.asarray(m.predict(
                                          ds.test_x[:40])))

    def test_unfusable_artifact_falls_back_to_staged(self, served):
        ds, m, _ = served
        dep_u = m.deploy(packed=False)
        assert not dep_u.fusable
        np.testing.assert_array_equal(
            np.asarray(dep_u.predict_features(ds.test_x[:16])),
            np.asarray(dep_u.predict(ds.test_x[:16])))


class TestDoubleBuffering:
    """The double-buffered batcher: identical responses at any depth."""

    def test_depths_agree(self, served):
        ds, _, dep = served
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=9,
                                  max_size=7, seed=11)
        sync, s_stats = serve_batches(dep, reqs, max_batch=24, depth=1)
        for depth in (2, 4):
            buf, b_stats = serve_batches(dep, reqs, max_batch=24,
                                         depth=depth)
            assert sync.keys() == buf.keys()
            for rid in sync:
                np.testing.assert_array_equal(sync[rid], buf[rid])
            # Batching/padding accounting is independent of the depth;
            # the depth field tags which latency semantics apply.
            assert b_stats["rows_padded"] == s_stats["rows_padded"]
            assert b_stats["batches"] == s_stats["batches"]
            assert b_stats["depth"] == depth and s_stats["depth"] == 1

    def test_bad_depth_rejected(self, served):
        ds, _, dep = served
        with pytest.raises(ValueError, match="depth"):
            serve_batches(dep, _reqs([4]), depth=0)


class TestTopkServing:
    """--topk serving through the hierarchical backend's fused top-k
    epilogue: per-request (n, k) class matrices whose first column is
    the argmax path, bit for bit (defaults are the exact S = G mode)."""

    def test_topk_first_column_matches_argmax(self, served):
        ds, m, dep = served
        dep_h = m.deploy(target="hierarchical")
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=6,
                                  max_size=9, seed=13)
        argmax, _ = serve_batches(dep, reqs, max_batch=16)
        topk, stats = serve_batches(dep_h, reqs, max_batch=16, topk=3)
        assert argmax.keys() == topk.keys()
        for rid in argmax:
            assert topk[rid].shape == (argmax[rid].shape[0], 3)
            np.testing.assert_array_equal(topk[rid][:, 0], argmax[rid])

    def test_topk_ranks_by_similarity(self, served):
        ds, m, _ = served
        dep_h = m.deploy(target="hierarchical")
        x = np.asarray(ds.test_x[:12], np.float32)
        cls, idx, sims = dep_h.predict_topk(x, 4)
        sims = np.asarray(sims)
        assert np.all(sims[:, :-1] >= sims[:, 1:])  # best-first
        assert cls.shape == idx.shape == sims.shape == (12, 4)

    def test_topk_with_fused_rejected(self, served):
        _, _, dep = served
        with pytest.raises(ValueError, match="topk"):
            serve_batches(dep, _reqs([4]), topk=2, fused=True)

    def test_topk_needs_predict_topk(self, served):
        # Backends without a top-k epilogue fail loudly, not silently.
        _, _, dep = served
        assert not hasattr(type(dep), "predict_topk")
        with pytest.raises(AttributeError):
            serve_batches(dep, _reqs([4]), topk=2)


class TestReportSchema:
    """The JSON report is a parsing contract; its key set is frozen.

    ``backend`` + ``devices`` (and the per-device throughput) make
    reports from different deployment backends and device counts
    comparable — asserted here for every registered backend.
    """

    KEYS = {
        "workload", "backend", "devices", "packed", "mode", "pipeline",
        "topk", "geometry", "requests", "rows", "wall_s", "qps",
        "rows_per_s", "rows_per_s_per_device", "resident_am_bytes",
        "am_memory_ratio", "metrics", "depth", "batches", "rows_real",
        "rows_padded", "pad_overhead",
        "lat_ms_min", "lat_ms_p50", "lat_ms_p95", "lat_ms_p99",
        "lat_ms_total",
        "service_ms_min", "service_ms_p50", "service_ms_p95",
        "service_ms_p99", "service_ms_total",
        "queue_ms_min", "queue_ms_p50", "queue_ms_p95", "queue_ms_p99",
        "queue_ms_total",
    }

    def test_schema_stable(self, served):
        ds, _, dep = served
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=4,
                                  max_size=6, seed=1)
        for fused in (False, True):
            _, stats = serve_batches(dep, reqs, max_batch=16,
                                     fused=fused)
            rep = build_report(dep, reqs, stats, wall_s=0.25,
                               fused=fused)
            assert set(rep) == self.KEYS
            assert rep["pipeline"] == ("fused" if fused else "staged")
            assert rep["topk"] == 0  # argmax serving
            assert rep["workload"] == "memhd_classify"
            assert rep["backend"] == "packed"
            assert rep["devices"] == 1
            assert rep["rows"] == sum(r.size for r in reqs)
            assert rep["qps"] == round(len(reqs) / 0.25, 1)
            assert rep["rows_per_s_per_device"] == rep["rows_per_s"]

    def test_unpacked_report_mode(self, served):
        ds, m, _ = served
        dep_u = m.deploy(packed=False)
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=2,
                                  max_size=4, seed=0)
        _, stats = serve_batches(dep_u, reqs, max_batch=8)
        rep = build_report(dep_u, reqs, stats, wall_s=0.1)
        assert set(rep) == self.KEYS
        assert rep["mode"] == "float" and rep["packed"] is False
        assert rep["backend"] == "unpacked"

    def test_topk_report_key(self, served):
        ds, m, _ = served
        dep_h = m.deploy(target="hierarchical")
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=2,
                                  max_size=4, seed=3)
        _, stats = serve_batches(dep_h, reqs, max_batch=8, topk=3)
        rep = build_report(dep_h, reqs, stats, wall_s=0.1, topk=3)
        assert set(rep) == self.KEYS
        assert rep["topk"] == 3
        assert rep["backend"] == "hierarchical"

    def test_imc_backend_report(self, served):
        ds, m, _ = served
        dep_i = m.deploy(target="imc")
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=2,
                                  max_size=4, seed=0)
        _, stats = serve_batches(dep_i, reqs, max_batch=8)
        rep = build_report(dep_i, reqs, stats, wall_s=0.1)
        assert set(rep) == self.KEYS
        assert rep["backend"] == "imc"
        assert rep["mode"] == "analog" and rep["packed"] is False
        assert rep["resident_am_bytes"] == dep_i.resident_bytes


class TestEmptyStream:
    """An empty request stream must not fabricate latency rows: every
    latency field is None (JSON null) and ``batches`` is 0."""

    LAT_FIELDS = [f"{p}_{s}" for p in ("lat_ms", "service_ms", "queue_ms")
                  for s in ("min", "p50", "p95", "p99", "total")]

    def test_empty_stream_null_latency(self, served):
        _, _, dep = served
        responses, stats = serve_batches(dep, [])
        assert responses == {}
        assert stats["batches"] == 0
        assert stats["rows_real"] == 0 and stats["rows_padded"] == 0
        # No rows -> no overhead RATIO: 0.0 would claim "measured, and
        # perfectly packed"; null says "nothing to measure".
        assert stats["pad_overhead"] is None
        for field in self.LAT_FIELDS:
            assert stats[field] is None, field

    def test_empty_stream_report_is_json(self, served):
        _, _, dep = served
        _, stats = serve_batches(dep, [])
        rep = build_report(dep, [], stats, wall_s=0.0)
        parsed = json.loads(json.dumps(rep))  # nulls survive the trip
        assert parsed["lat_ms_min"] is None
        assert parsed["batches"] == 0
        assert parsed["qps"] == 0.0


class TestLatencyDecomposition:
    """queue_ms + service_ms == lat_ms: the pipeline queue wait that
    depth > 1 used to fold silently into lat_ms is now its own field."""

    def _serve(self, served, depth, n=14):
        ds, _, dep = served
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=n,
                                  max_size=6, seed=3)
        return serve_batches(dep, reqs, max_batch=8, depth=depth)

    def test_depth1_queue_is_zero(self, served):
        _, stats = self._serve(served, depth=1)
        assert stats["batches"] >= 2
        assert stats["queue_ms_total"] == 0.0
        assert stats["service_ms_total"] == pytest.approx(
            stats["lat_ms_total"], abs=0.01 * stats["batches"] + 0.01)

    @pytest.mark.parametrize("depth", [2, 4])
    def test_sum_consistent_at_depth(self, served, depth):
        _, stats = self._serve(served, depth=depth)
        assert stats["batches"] >= 2
        # Per batch queue + service == lat exactly; the fields round to
        # 3 decimals, so totals agree within the rounding budget.
        tol = 0.002 * stats["batches"] + 0.01
        assert (stats["service_ms_total"] + stats["queue_ms_total"]
                == pytest.approx(stats["lat_ms_total"], abs=tol))
        for s in ("min", "p50", "p95", "p99", "total"):
            assert stats[f"queue_ms_{s}"] >= 0.0
            assert stats[f"service_ms_{s}"] >= 0.0


class TestObsIntegration:
    """The acceptance contract: instrumented serving is bit-exact with
    direct prediction, steady-state serving never recompiles, the
    metrics section carries the dispatch-tier breakdown, and the trace
    export is valid Chrome trace-event JSON."""

    def test_predictions_bit_exact_with_uninstrumented(self, served):
        ds, _, dep = served
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=8,
                                  max_size=7, seed=9)
        responses, _ = serve_batches(dep, reqs, max_batch=16, depth=4)
        for r in reqs:
            want = np.asarray(dep.predict(r.feats))
            np.testing.assert_array_equal(responses[r.rid], want)

    def test_steady_state_recompiles_zero(self, served):
        ds, _, dep = served
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=10,
                                  max_size=6, seed=4)
        # Warmup pass compiles every padded shape the stream hits...
        serve_batches(dep, reqs, max_batch=16, depth=4)
        # ...so the steady-state pass must compile NOTHING new.
        with obs.count_compiles() as steady:
            _, stats = serve_batches(dep, reqs, max_batch=16,
                                     warmup=False, depth=4)
        assert steady() == 0
        rep = build_report(
            dep, reqs, stats, wall_s=0.1,
            metrics=metrics_summary(recompiles_steady_state=steady()))
        assert rep["metrics"]["recompiles_steady_state"] == 0
        with obs.assert_no_recompiles("steady-state serving"):
            serve_batches(dep, reqs, max_batch=16, warmup=False,
                          depth=4)

    def test_non_f32_stream_warmup_matches_dtype(self, served):
        """Warmup must pre-compile the dtype the stream actually
        carries: a float16 stream warmed with float32 zeros would hit
        cold jit signatures on every steady-state batch (the regression
        this pins down — warmup now reads ``requests[0].feats.dtype``).
        """
        ds, _, dep = served
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=10,
                                  max_size=6, seed=4)
        reqs = [Request(rid=r.rid,
                        feats=r.feats.astype(np.float16))
                for r in reqs]
        serve_batches(dep, reqs, max_batch=16, depth=2)  # warmup pass
        with obs.assert_no_recompiles("non-f32 steady-state serving"):
            responses, _ = serve_batches(dep, reqs, max_batch=16,
                                         warmup=False, depth=2)
        for r in reqs:  # and the f16 stream still predicts correctly
            np.testing.assert_array_equal(
                responses[r.rid], np.asarray(dep.predict(r.feats)))

    def test_metrics_section_has_dispatch_tiers(self, served):
        ds, _, dep = served
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=4,
                                  max_size=5, seed=6)
        _, stats = serve_batches(dep, reqs, max_batch=16)
        rep = build_report(dep, reqs, stats, wall_s=0.1)
        tiers = rep["metrics"]["dispatch_tiers"]
        # The packed backend serves through pack_rows + the packed scan.
        assert "am_search_packed" in tiers
        assert tiers["am_search_packed"].get("pallas", 0) >= 1
        assert rep["metrics"]["compiles_total"] >= 0
        json.dumps(rep)  # the whole report stays JSON-serializable

    def test_trace_export_is_valid_chrome_trace(self, served, tmp_path):
        ds, _, dep = served
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=5,
                                  max_size=5, seed=8)
        obs.TRACER.reset()
        serve_batches(dep, reqs, max_batch=16, depth=2)
        path = obs.export_chrome_trace(str(tmp_path / "trace.json"))
        with open(path) as f:
            trace = json.load(f)
        events = trace["traceEvents"]
        assert events, "serving emitted no spans"
        names = {e["name"] for e in events}
        assert {"host_prep", "pad", "dispatch", "device_wait"} <= names
        for e in events:
            assert e["ph"] == "X"
            assert e["dur"] >= 0 and e["ts"] > 0
            assert isinstance(e["args"]["span_id"], int)
        # pad spans nest under host_prep: parent ids resolve.
        by_id = {e["args"]["span_id"]: e for e in events}
        pads = [e for e in events if e["name"] == "pad"]
        assert pads
        for p in pads:
            parent = by_id[p["args"]["parent_id"]]
            assert parent["name"] == "host_prep"
