"""serve_memhd driver: batcher accounting, fused-vs-staged parity on
ragged request streams, and the JSON report schema contract."""
import jax
import numpy as np
import pytest

from repro.launch.serve_memhd import (Request, build_report, make_batches,
                                      serve_batches, synthetic_requests)


@pytest.fixture(scope="module")
def served(small_hdc_data):
    """A small trained model deployed packed (fused-servable)."""
    from repro.core import EncoderConfig, MemhdConfig, MemhdModel
    ds = small_hdc_data
    enc = EncoderConfig(kind="projection", features=ds.features, dim=128)
    amc = MemhdConfig(dim=128, columns=32, classes=ds.classes,
                      epochs=1, kmeans_iters=3)
    m = MemhdModel.create(jax.random.key(0), enc, amc)
    m, _ = m.fit(jax.random.key(1), ds.train_x, ds.train_y)
    return ds, m, m.deploy(packed=True)


def _reqs(sizes, f=4):
    return [Request(rid=i, feats=np.zeros((n, f), np.float32))
            for i, n in enumerate(sizes)]


class TestBatcherAccounting:
    """Padding accounting of the greedy batcher, end to end."""

    def test_pad_accounting_exact(self, served):
        ds, _, dep = served
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=7,
                                  max_size=11, seed=5)
        _, stats = serve_batches(dep, reqs, max_batch=16, tile=8)
        sizes = [r.size for r in reqs]
        batches = make_batches(reqs, 16)
        want_padded = sum(-(-sum(r.size for r in b) // 8) * 8
                          for b in batches)
        assert stats["rows_real"] == sum(sizes)
        assert stats["rows_padded"] == want_padded
        assert stats["batches"] == len(batches)
        assert stats["pad_overhead"] == round(
            want_padded / sum(sizes) - 1, 3)
        assert stats["lat_ms_total"] >= 0

    def test_every_batch_tile_aligned(self, served):
        ds, _, dep = served
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=5,
                                  max_size=13, seed=2)
        _, stats = serve_batches(dep, reqs, max_batch=32, tile=8)
        assert stats["rows_padded"] % 8 == 0
        assert stats["rows_padded"] >= stats["rows_real"]

    def test_batcher_never_splits_requests(self):
        batches = make_batches(_reqs([5, 5, 5, 20, 3]), 12)
        flat = [r.rid for b in batches for r in b]
        assert sorted(flat) == [0, 1, 2, 3, 4]  # every request, once
        assert all(sum(r.size for r in b) <= 12
                   for b in batches if len(b) > 1)


class TestFusedServing:
    """--fused serving: single-dispatch pipeline, bit-exact with staged."""

    def test_fused_vs_staged_parity_on_ragged_stream(self, served):
        ds, _, dep = served
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=11,
                                  max_size=9, seed=7)
        staged, s_stats = serve_batches(dep, reqs, max_batch=24)
        fused, f_stats = serve_batches(dep, reqs, max_batch=24,
                                       fused=True)
        assert staged.keys() == fused.keys()
        for rid in staged:
            np.testing.assert_array_equal(staged[rid], fused[rid])
        # Identical batching either way — only the kernel path differs.
        assert s_stats["rows_padded"] == f_stats["rows_padded"]
        assert s_stats["batches"] == f_stats["batches"]

    def test_predict_features_matches_predict(self, served):
        ds, m, dep = served
        got = np.asarray(dep.predict_features(ds.test_x[:40]))
        np.testing.assert_array_equal(got,
                                      np.asarray(dep.predict(
                                          ds.test_x[:40])))
        np.testing.assert_array_equal(got,
                                      np.asarray(m.predict(
                                          ds.test_x[:40])))

    def test_unfusable_artifact_falls_back_to_staged(self, served):
        ds, m, _ = served
        dep_u = m.deploy(packed=False)
        assert not dep_u.fusable
        np.testing.assert_array_equal(
            np.asarray(dep_u.predict_features(ds.test_x[:16])),
            np.asarray(dep_u.predict(ds.test_x[:16])))


class TestDoubleBuffering:
    """The double-buffered batcher: identical responses at any depth."""

    def test_depths_agree(self, served):
        ds, _, dep = served
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=9,
                                  max_size=7, seed=11)
        sync, s_stats = serve_batches(dep, reqs, max_batch=24, depth=1)
        for depth in (2, 4):
            buf, b_stats = serve_batches(dep, reqs, max_batch=24,
                                         depth=depth)
            assert sync.keys() == buf.keys()
            for rid in sync:
                np.testing.assert_array_equal(sync[rid], buf[rid])
            # Batching/padding accounting is independent of the depth;
            # the depth field tags which latency semantics apply.
            assert b_stats["rows_padded"] == s_stats["rows_padded"]
            assert b_stats["batches"] == s_stats["batches"]
            assert b_stats["depth"] == depth and s_stats["depth"] == 1

    def test_bad_depth_rejected(self, served):
        ds, _, dep = served
        with pytest.raises(ValueError, match="depth"):
            serve_batches(dep, _reqs([4]), depth=0)


class TestTopkServing:
    """--topk serving through the hierarchical backend's fused top-k
    epilogue: per-request (n, k) class matrices whose first column is
    the argmax path, bit for bit (defaults are the exact S = G mode)."""

    def test_topk_first_column_matches_argmax(self, served):
        ds, m, dep = served
        dep_h = m.deploy(target="hierarchical")
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=6,
                                  max_size=9, seed=13)
        argmax, _ = serve_batches(dep, reqs, max_batch=16)
        topk, stats = serve_batches(dep_h, reqs, max_batch=16, topk=3)
        assert argmax.keys() == topk.keys()
        for rid in argmax:
            assert topk[rid].shape == (argmax[rid].shape[0], 3)
            np.testing.assert_array_equal(topk[rid][:, 0], argmax[rid])

    def test_topk_ranks_by_similarity(self, served):
        ds, m, _ = served
        dep_h = m.deploy(target="hierarchical")
        x = np.asarray(ds.test_x[:12], np.float32)
        cls, idx, sims = dep_h.predict_topk(x, 4)
        sims = np.asarray(sims)
        assert np.all(sims[:, :-1] >= sims[:, 1:])  # best-first
        assert cls.shape == idx.shape == sims.shape == (12, 4)

    def test_topk_with_fused_rejected(self, served):
        _, _, dep = served
        with pytest.raises(ValueError, match="topk"):
            serve_batches(dep, _reqs([4]), topk=2, fused=True)

    def test_topk_needs_predict_topk(self, served):
        # Backends without a top-k epilogue fail loudly, not silently.
        _, _, dep = served
        assert not hasattr(type(dep), "predict_topk")
        with pytest.raises(AttributeError):
            serve_batches(dep, _reqs([4]), topk=2)


class TestReportSchema:
    """The JSON report is a parsing contract; its key set is frozen.

    ``backend`` + ``devices`` (and the per-device throughput) make
    reports from different deployment backends and device counts
    comparable — asserted here for every registered backend.
    """

    KEYS = {
        "workload", "backend", "devices", "packed", "mode", "pipeline",
        "topk", "geometry", "requests", "rows", "wall_s", "qps",
        "rows_per_s", "rows_per_s_per_device", "resident_am_bytes",
        "am_memory_ratio", "depth", "batches", "rows_real",
        "rows_padded", "pad_overhead", "lat_ms_min", "lat_ms_p50",
        "lat_ms_p95", "lat_ms_p99", "lat_ms_total",
    }

    def test_schema_stable(self, served):
        ds, _, dep = served
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=4,
                                  max_size=6, seed=1)
        for fused in (False, True):
            _, stats = serve_batches(dep, reqs, max_batch=16,
                                     fused=fused)
            rep = build_report(dep, reqs, stats, wall_s=0.25,
                               fused=fused)
            assert set(rep) == self.KEYS
            assert rep["pipeline"] == ("fused" if fused else "staged")
            assert rep["topk"] == 0  # argmax serving
            assert rep["workload"] == "memhd_classify"
            assert rep["backend"] == "packed"
            assert rep["devices"] == 1
            assert rep["rows"] == sum(r.size for r in reqs)
            assert rep["qps"] == round(len(reqs) / 0.25, 1)
            assert rep["rows_per_s_per_device"] == rep["rows_per_s"]

    def test_unpacked_report_mode(self, served):
        ds, m, _ = served
        dep_u = m.deploy(packed=False)
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=2,
                                  max_size=4, seed=0)
        _, stats = serve_batches(dep_u, reqs, max_batch=8)
        rep = build_report(dep_u, reqs, stats, wall_s=0.1)
        assert set(rep) == self.KEYS
        assert rep["mode"] == "float" and rep["packed"] is False
        assert rep["backend"] == "unpacked"

    def test_topk_report_key(self, served):
        ds, m, _ = served
        dep_h = m.deploy(target="hierarchical")
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=2,
                                  max_size=4, seed=3)
        _, stats = serve_batches(dep_h, reqs, max_batch=8, topk=3)
        rep = build_report(dep_h, reqs, stats, wall_s=0.1, topk=3)
        assert set(rep) == self.KEYS
        assert rep["topk"] == 3
        assert rep["backend"] == "hierarchical"

    def test_imc_backend_report(self, served):
        ds, m, _ = served
        dep_i = m.deploy(target="imc")
        reqs = synthetic_requests(np.asarray(ds.test_x), n_requests=2,
                                  max_size=4, seed=0)
        _, stats = serve_batches(dep_i, reqs, max_batch=8)
        rep = build_report(dep_i, reqs, stats, wall_s=0.1)
        assert set(rep) == self.KEYS
        assert rep["backend"] == "imc"
        assert rep["mode"] == "analog" and rep["packed"] is False
        assert rep["resident_am_bytes"] == dep_i.resident_bytes
