"""Perf-trajectory harness: frozen BENCH_*.json schema, regression-gate
behavior on synthetic baselines, run.py --only/--fast selection semantics
(subprocess), benchmarks/*.py registration completeness, and the
kernel-autotune cache round-trip + tuned-vs-default bit-exactness for
all five tunable kernels."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import gate, record
from repro.kernels import autotune

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
REPO_SRC = os.path.join(REPO_ROOT, "src")


@pytest.fixture(autouse=True)
def _no_leaked_recorder():
    """Every test starts and ends without a process-global recorder."""
    record.finish(write=False)
    yield
    record.finish(write=False)


class TestRecorderSchema:
    """BENCH_<name>.json is a parsing contract; its key set is frozen."""

    def test_frozen_top_level_schema(self, tmp_path):
        record.start("demo", out_dir=str(tmp_path))
        from benchmarks.common import row
        row("demo/metric_a", 12.5, "acc=0.9")
        row("demo/metric_b", 0.0, 42, cycles=7)
        path = record.finish()
        assert path == str(tmp_path / "BENCH_demo.json")
        with open(path) as f:
            data = json.load(f)
        assert set(data) == set(record.TOP_LEVEL_KEYS)
        assert data["schema_version"] == record.SCHEMA_VERSION == 1
        assert data["bench"] == "demo"
        assert isinstance(data["created_unix"], int)
        for metric in data["metrics"].values():
            assert record.METRIC_REQUIRED_KEYS <= set(metric)
        assert data["metrics"]["demo/metric_a"]["us_per_call"] == 12.5
        assert data["metrics"]["demo/metric_a"]["derived"] == "acc=0.9"
        assert data["metrics"]["demo/metric_b"]["cycles"] == 7

    def test_timing_stats_true_median_and_min(self):
        # The old sorted[n // 2] was the UPPER-middle sample for even n.
        stats = record.timing_stats([4e-6, 1e-6, 2e-6, 3e-6])
        assert stats["p50_us"] == pytest.approx(2.5)  # not 3.0
        assert stats["min_us"] == pytest.approx(1.0)
        assert stats["n_samples"] == 4
        assert stats["p95_us"] == pytest.approx(4.0)
        assert stats["p99_us"] == pytest.approx(4.0)
        odd = record.timing_stats([3e-6, 1e-6, 2e-6])
        assert odd["p50_us"] == pytest.approx(2.0)

    def test_time_fn_attaches_stats_to_row(self, tmp_path):
        from benchmarks.common import row, time_fn
        record.start("timed", out_dir=str(tmp_path))
        us = time_fn(lambda: np.arange(8), iters=4)
        row("timed/thing", us, "x")
        path = record.finish()
        with open(path) as f:
            metric = json.load(f)["metrics"]["timed/thing"]
        assert record.TIMING_KEYS <= set(metric)
        assert metric["p50_us"] == metric["us_per_call"] == us
        assert metric["min_us"] <= metric["p50_us"] <= metric["p95_us"]
        assert metric["n_samples"] == 4
        assert len(metric["samples_us"]) == 4

    def test_row_and_time_fn_without_recorder_are_noops(self):
        from benchmarks.common import row, time_fn
        assert record.active() is None
        us = time_fn(lambda: 1, iters=2)
        assert row("orphan", us, "ok").startswith("orphan,")

    def test_from_report_wraps_serving_reports(self, tmp_path):
        report = {"workload": "memhd_classify", "backend": "packed",
                  "qps": 123.4, "lat_ms_p50": 2.0, "bit_exact": True,
                  "devices": 2}
        path = record.from_report("serve_memhd", report,
                                  out_dir=str(tmp_path))
        with open(path) as f:
            data = json.load(f)
        assert set(data) == set(record.TOP_LEVEL_KEYS)
        assert data["bench"] == "serve_memhd"
        # Strings/bools -> meta; numbers -> metrics; lat_ms_* -> timed.
        assert data["meta"]["workload"] == "memhd_classify"
        assert data["meta"]["bit_exact"] is True
        assert data["metrics"]["qps"]["value"] == 123.4
        assert data["metrics"]["qps"]["us_per_call"] == 0.0
        assert data["metrics"]["lat_ms_p50"]["us_per_call"] == 2000.0


def _write_record(dirpath, bench, metrics):
    os.makedirs(dirpath, exist_ok=True)
    rec = {"schema_version": record.SCHEMA_VERSION, "bench": bench,
           "created_unix": 0, "git_sha": None, "jax_backend": "cpu",
           "jax_version": "0", "meta": {}, "metrics": metrics}
    with open(os.path.join(dirpath, f"BENCH_{bench}.json"), "w") as f:
        json.dump(rec, f)


def _timed(us):
    return {"us_per_call": us, "derived": "x", "min_us": us}


class TestGate:
    """gate.py semantics on synthetic baseline/current trees."""

    def _dirs(self, tmp_path):
        return str(tmp_path / "base"), str(tmp_path / "cur")

    def test_identical_passes(self, tmp_path):
        base, cur = self._dirs(tmp_path)
        for d in (base, cur):
            _write_record(d, "k", {"m": _timed(1000.0)})
        assert gate.main(["--baseline", base, "--current", cur]) == 0

    def test_slowdown_fails(self, tmp_path, capsys):
        base, cur = self._dirs(tmp_path)
        _write_record(base, "k", {"m": _timed(1000.0)})
        _write_record(cur, "k", {"m": _timed(3000.0)})  # 200% > 100%
        assert gate.main(["--baseline", base, "--current", cur]) == 1
        assert "slower" in capsys.readouterr().err

    def test_threshold_is_respected(self, tmp_path):
        base, cur = self._dirs(tmp_path)
        _write_record(base, "k", {"m": _timed(1000.0)})
        _write_record(cur, "k", {"m": _timed(1300.0)})  # +30%
        args = ["--baseline", base, "--current", cur]
        assert gate.main(args) == 0  # default 100%
        assert gate.main(args + ["--max-slowdown-pct", "10"]) == 1

    def test_speedup_passes(self, tmp_path):
        base, cur = self._dirs(tmp_path)
        _write_record(base, "k", {"m": _timed(9000.0)})
        _write_record(cur, "k", {"m": _timed(1000.0)})
        assert gate.main(["--baseline", base, "--current", cur]) == 0

    def test_missing_metric_fails(self, tmp_path, capsys):
        base, cur = self._dirs(tmp_path)
        _write_record(base, "k", {"m": _timed(1000.0),
                                  "gone": {"us_per_call": 0.0,
                                           "derived": "1"}})
        _write_record(cur, "k", {"m": _timed(1000.0)})
        assert gate.main(["--baseline", base, "--current", cur]) == 1
        assert "missing" in capsys.readouterr().err

    def test_missing_bench_fails(self, tmp_path):
        base, cur = self._dirs(tmp_path)
        _write_record(base, "k", {"m": _timed(1.0)})
        _write_record(base, "gone", {"m": _timed(1.0)})
        _write_record(cur, "k", {"m": _timed(1.0)})
        assert gate.main(["--baseline", base, "--current", cur]) == 1

    def test_new_bench_and_metric_pass(self, tmp_path):
        base, cur = self._dirs(tmp_path)
        _write_record(base, "k", {"m": _timed(1000.0)})
        _write_record(cur, "k", {"m": _timed(1000.0),
                                 "extra": _timed(5.0)})
        _write_record(cur, "brand_new", {"m": _timed(1.0)})
        assert gate.main(["--baseline", base, "--current", cur]) == 0

    def test_lost_timing_fails(self, tmp_path, capsys):
        base, cur = self._dirs(tmp_path)
        _write_record(base, "k", {"m": _timed(1000.0)})
        _write_record(cur, "k", {"m": {"us_per_call": 0.0,
                                       "derived": "x"}})
        assert gate.main(["--baseline", base, "--current", cur]) == 1
        assert "no timing" in capsys.readouterr().err

    def test_noise_floor_ignores_tiny_timings(self, tmp_path):
        base, cur = self._dirs(tmp_path)
        _write_record(base, "k", {"m": _timed(3.0)})
        _write_record(cur, "k", {"m": _timed(30.0)})  # 10x, but < 50us
        assert gate.main(["--baseline", base, "--current", cur]) == 0

    def test_empty_sides_fail_loudly(self, tmp_path, capsys):
        base, cur = self._dirs(tmp_path)
        os.makedirs(base), os.makedirs(cur)
        assert gate.main(["--baseline", base, "--current", cur]) == 1
        _write_record(base, "k", {"m": _timed(1.0)})
        assert gate.main(["--baseline", base, "--current", cur]) == 1
        assert "no current records" in capsys.readouterr().err

    def test_update_baselines_roundtrip(self, tmp_path):
        base, cur = self._dirs(tmp_path)
        _write_record(cur, "k", {"m": _timed(77.0)})
        assert gate.main(["--baseline", base, "--current", cur,
                          "--update-baselines"]) == 0
        assert gate.main(["--baseline", base, "--current", cur]) == 0

    def test_schema_version_mismatch_fails(self, tmp_path):
        base, cur = self._dirs(tmp_path)
        _write_record(base, "k", {"m": _timed(1000.0)})
        _write_record(cur, "k", {"m": _timed(1000.0)})
        fn = os.path.join(cur, "BENCH_k.json")
        with open(fn) as f:
            data = json.load(f)
        data["schema_version"] = 999
        with open(fn, "w") as f:
            json.dump(data, f)
        assert gate.main(["--baseline", base, "--current", cur]) == 1


def _run_benchrun(*args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", *args], cwd=REPO_ROOT,
        env=env, capture_output=True, text=True, timeout=timeout)


class TestRunSelection:
    """--only/--fast semantics of benchmarks.run, via subprocess.

    The regression this pins: ``--fast --only fig3`` used to intersect
    the two filters, run NOTHING, and still print the all-passed
    banner with exit code 0.
    """

    def test_only_overrides_fast(self):
        r = _run_benchrun("--fast", "--only", "fig3", "--list")
        assert r.returncode == 0, r.stderr
        listed = [ln.split("\t")[0] for ln in r.stdout.splitlines()
                  if "\t" in ln]
        assert listed == ["fig3"]  # fig3 is NOT in FAST; it still runs
        assert "overrides --fast" in r.stdout

    def test_zero_match_exits_nonzero(self):
        for extra in ([], ["--fast"]):
            r = _run_benchrun(*extra, "--only", "nosuchbench")
            assert r.returncode == 2
            assert "matched zero" in r.stderr
            assert "all" not in r.stdout or "passed" not in r.stdout

    def test_ambiguous_prefix_resolution_is_printed(self):
        r = _run_benchrun("--only", "fig", "--list")
        assert r.returncode == 0, r.stderr
        (resolution,) = [ln for ln in r.stdout.splitlines()
                         if ln.startswith("# --only fig ->")]
        for name in ("fig3", "fig4", "fig5", "fig6", "fig7",
                     "fig_robustness"):
            assert name in resolution

    def test_fast_list_is_the_fast_set(self):
        r = _run_benchrun("--fast", "--list")
        assert r.returncode == 0, r.stderr
        listed = {ln.split("\t")[0] for ln in r.stdout.splitlines()
                  if "\t" in ln}
        from benchmarks.run import FAST
        assert listed == FAST

    @pytest.mark.slow
    def test_recorded_run_end_to_end(self, tmp_path):
        out = str(tmp_path / "rec")
        r = _run_benchrun("--only", "table2", "--record-dir", out)
        assert r.returncode == 0, r.stderr
        assert "# table2 done" in r.stdout
        path = os.path.join(out, "BENCH_table2.json")
        assert os.path.exists(path), os.listdir(tmp_path)
        with open(path) as f:
            data = json.load(f)
        assert set(data) == set(record.TOP_LEVEL_KEYS)
        assert data["bench"] == "table2"
        assert any(k.startswith("table2/") for k in data["metrics"])
        # A recorded run gates green against itself.
        assert gate.main(["--baseline", out, "--current", out]) == 0


# Smallest geometries the kernels are contracted for (D one lane tile).
SMALL_DIMS = {
    "am_search_packed": {"D": 128, "C": 32},
    "am_shortlist": {"D": 128, "G": 32, "S": 4},
    "am_search_sparse": {"D": 128, "T": 2, "K": 3},
    "am_search_multibit": {"D": 128, "C": 32, "bits": 2},
    "encode_pack": {"f": 40, "D": 128},
    "qail_update": {"D": 128, "C": 32},
}


class TestBenchRegistration:
    """Every benchmarks/*.py module is registered in run.py BENCHES (or
    is explicitly harness infrastructure) — pins the orphan-bench class
    of bug (hillclimb shipped unreachable from the orchestrator)."""

    # Harness plumbing, not benches: never registered.
    EXEMPT = {"run", "common", "record", "gate", "__init__"}

    def test_every_bench_module_is_registered(self):
        from benchmarks.run import BENCHES
        bench_dir = os.path.join(REPO_ROOT, "benchmarks")
        modules = {os.path.splitext(f)[0] for f in os.listdir(bench_dir)
                   if f.endswith(".py")}
        registered = {mod.rsplit(".", 1)[-1] for _, mod in BENCHES}
        unregistered = modules - registered - self.EXEMPT
        assert not unregistered, (
            f"benchmarks modules not registered in run.py BENCHES and "
            f"not in the EXEMPT harness set: {sorted(unregistered)}")
        # And the registry never points at a module that doesn't exist.
        assert registered <= modules

    def test_registered_names_are_unique(self):
        from benchmarks.run import BENCHES, FAST
        names = [n for n, _ in BENCHES]
        assert len(names) == len(set(names))
        assert FAST <= set(names)


class TestAutotune:
    """Cache round-trip + tuned-vs-default bit-exactness, all kernels."""

    @pytest.fixture(autouse=True)
    def _tmp_cache(self, tmp_path, monkeypatch):
        self.cache = str(tmp_path / "autotune_cache.json")
        monkeypatch.setenv(autotune.CACHE_ENV, self.cache)

    def test_cache_roundtrip(self):
        dims = SMALL_DIMS["am_search_packed"]
        entry = autotune.autotune_kernel("am_search_packed", dims,
                                         batch=64, iters=1)
        assert os.path.exists(self.cache)
        geom = autotune.geometry_key("am_search_packed", **dims)
        loaded = autotune.lookup("am_search_packed", geom)
        assert loaded is not None
        assert loaded["block_b"] == entry["block_b"]
        assert loaded["geometry"] == geom == "D128_C32"
        assert autotune.tuned_block_b("am_search_packed",
                                      **dims) == entry["block_b"]
        # Unknown geometry falls back to the kernel default.
        assert (autotune.tuned_block_b("am_search_packed", D=999, C=7)
                == autotune.KERNELS["am_search_packed"].default_block_b)

    @pytest.mark.parametrize("kernel", sorted(autotune.KERNELS))
    def test_tuned_vs_default_bit_exact(self, kernel):
        spec = autotune.KERNELS[kernel]
        dims = SMALL_DIMS[kernel]
        # batch > smallest candidates: multi-block tilings are exercised.
        args = spec.make_inputs(np.random.default_rng(3), 96, dims)
        want = [np.asarray(x) for x in jax.tree.leaves(spec.run_ref(*args))]
        for bb in set(spec.candidates) | {spec.default_block_b}:
            got = jax.tree.leaves(spec.run(bb, *args))
            for g, w in zip(got, want):
                np.testing.assert_array_equal(np.asarray(g), w,
                                              err_msg=f"{kernel}@{bb}")

    def test_entry_beats_or_ties_default_and_is_recorded(self):
        entry = autotune.autotune_kernel(
            "qail_update", SMALL_DIMS["qail_update"], batch=128, iters=1)
        assert entry["best_us"] <= entry["default_us"]
        assert str(min(entry["block_b"], 128)) in entry["candidates_us"]
        assert entry["backend"] == "cpu"

    def test_ops_dispatch_consults_cache(self):
        from repro.kernels import ops, ref
        dims = SMALL_DIMS["am_search_packed"]
        geom = autotune.geometry_key("am_search_packed", **dims)
        autotune.save_entry({
            "kernel": "am_search_packed",
            "backend": jax.default_backend(),
            "geometry": geom, "block_b": 32})
        assert ops.tuned_block_b("am_search_packed", None, **dims) == 32
        assert ops.tuned_block_b("am_search_packed", 64, **dims) == 64
        # And the cached tiling serves bit-exact predictions.
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.choice([-1., 1.], size=(50, 128))
                        .astype(np.float32))
        am = jnp.asarray(rng.choice([-1., 1.], size=(32, 128))
                         .astype(np.float32))
        qp, apt = ref.pack_rows(q), ref.pack_rows(am).T
        gi, gs = ops.am_search_packed(qp, apt, n_dims=128)
        wi, ws = ref.am_search_packed(qp, apt, 128)
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))

    def test_vmem_budget_skips_and_can_exhaust(self):
        dims = SMALL_DIMS["am_search_packed"]
        with pytest.raises(RuntimeError, match="VMEM budget"):
            autotune.autotune_kernel("am_search_packed", dims, batch=64,
                                     iters=1, vmem_budget_mb=1e-6)
        entry = autotune.autotune_kernel(
            "am_search_packed", dims, batch=1024, iters=1,
            vmem_budget_mb=1.0)  # 1 MB: only block_b=64 fits
        assert entry["skipped_vmem"]

    def test_geometry_key_requires_dims(self):
        with pytest.raises(KeyError, match="missing"):
            autotune.geometry_key("encode_pack", D=64)
