"""Batched serving example: hybrid-cache decoding (deliverable (b)).

Serves a Hymba-family smoke model — the most cache-diverse arch
(sliding-window attention ring buffers + global layers + SSM states in
the same stack) — with batched greedy decoding through the production
``decode_step``.

  PYTHONPATH=src python examples/serve_lm.py --batch 4 --gen 48
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import generate
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()

    mcfg = get_smoke_config(args.arch)
    params, _ = T.init_params(jax.random.key(0), mcfg)
    prompts = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0,
        mcfg.vocab_size, dtype=jnp.int32)

    t0 = time.time()
    out = generate(mcfg, params, prompts, args.gen)
    dt = time.time() - t0
    print(f"arch={mcfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}")
    print(f"throughput: {args.batch * args.gen / dt:.1f} new tok/s "
          f"(CPU, untrained weights)")
    for i in range(min(2, args.batch)):
        print(f"  seq[{i}]: {np.asarray(out[i, args.prompt_len:])[:12]}...")


if __name__ == "__main__":
    main()
