"""IMC mapping report + MemhdHead-over-backbone example.

Part 1 reprints the paper's Table II from the closed-form cost model for
any array geometry (try --array 64 or 256 to explore beyond the paper).

Part 2 demonstrates DESIGN.md §Arch-applicability: the MEMHD multi-
centroid AM as a drop-in classification head over pooled features from
the InternVL2-family smoke backbone — classifying synthetic "image
classes" from patch embeddings, deployable on one 128x128 array.

  PYTHONPATH=src python examples/imc_mapping_report.py
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.head import MemhdHead
from repro.core.imc import ImcArrayConfig, table2


def part1_table2(array: int):
    arr = ImcArrayConfig(rows=array, cols=array)
    print(f"=== Table II (array {array}x{array}) ===")
    for group, methods in table2(arr).items():
        print(f"\n[{group}]")
        print(f"{'method':>16} {'EM cyc':>7} {'AM cyc':>7} {'arrays':>7} "
              f"{'AM util':>8}")
        for name, cost in methods.items():
            print(f"{name:>16} {cost.em.cycles:>7} {cost.am.cycles:>7} "
                  f"{cost.total_arrays:>7} {cost.am.utilization:>8.2%}")


def part2_backbone_head():
    from repro.configs import get_smoke_config
    from repro.models import transformer as T

    print("\n=== MemhdHead over InternVL2-family backbone features ===")
    mcfg = get_smoke_config("internvl2-2b")
    params, _ = T.init_params(jax.random.key(0), mcfg)

    # Synthetic 6-class "image" task: class-dependent patch statistics.
    rng = np.random.default_rng(0)
    n_per, k = 60, 6
    protos = rng.normal(0, 1.0, (k, 4, 1024))
    feats, labels = [], []
    for c in range(k):
        for _ in range(n_per):
            mix = protos[c, rng.integers(0, 4)]
            feats.append(mix + rng.normal(0, 0.8, (mcfg.n_patches, 1024)))
            labels.append(c)
    feats = jnp.asarray(np.stack(feats), jnp.float32)
    labels = jnp.asarray(np.asarray(labels), jnp.int32)

    # Backbone forward -> pooled hidden features.
    toks = jnp.zeros((feats.shape[0], 8), jnp.int32)
    batch = {"tokens": toks, "patch_feats": feats,
             "targets": toks}
    hidden = []
    fwd = jax.jit(lambda p, b: T.forward(p, mcfg, b)[1]["final_hidden"])
    for i in range(0, feats.shape[0], 64):
        sub = {k2: v[i:i + 64] for k2, v in batch.items()}
        hidden.append(MemhdHead.pool(fwd(params, sub)))
    pooled = jnp.concatenate(hidden, axis=0)

    n_train = int(0.8 * pooled.shape[0])
    perm = jax.random.permutation(jax.random.key(2), pooled.shape[0])
    tr, te = perm[:n_train], perm[n_train:]

    head = MemhdHead.create(jax.random.key(3), pooled.shape[-1],
                            n_classes=k, dim=128, columns=128, epochs=15)
    head, _ = head.fit(jax.random.key(4), pooled[tr], labels[tr])
    acc = head.score(pooled[te], labels[te])
    print(f"head accuracy on synthetic 6-class task: {acc:.3f} "
          f"(memory {head.memory_kb:.1f} KB, one-shot search on one "
          f"128x128 array)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--array", type=int, default=128)
    args = ap.parse_args()
    part1_table2(args.array)
    part2_backbone_head()
