"""Quickstart: the full MEMHD pipeline (Fig. 2 of the paper) in ~40 lines.

Encode -> cluster-init (R=0.8, confusion-driven allocation) -> 1-bit
quantization -> quantization-aware iterative learning -> one-shot
associative search, plus the IMC deployment accounting for the trained
model.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import EncoderConfig, MemhdConfig, MemhdModel
from repro.core.imc import ImcArrayConfig
from repro.data import load_dataset


def main():
    ds = load_dataset("mnist", train_per_class=400, test_per_class=80)
    print(f"dataset: {ds.name} ({ds.source}), {ds.train_x.shape[0]} train")

    enc = EncoderConfig(kind="projection", features=ds.features, dim=128)
    am = MemhdConfig(dim=128, columns=128, classes=ds.classes,
                     init_ratio=0.8, epochs=20, lr=0.01)
    model = MemhdModel.create(jax.random.key(0), enc, am)

    model, hist = model.fit(jax.random.key(1), ds.train_x, ds.train_y,
                            eval_feats=ds.test_x, eval_labels=ds.test_y)
    curve = [r for r in hist["curve"] if "eval_acc" in r]
    print(f"init acc {curve[0]['eval_acc']:.3f} -> "
          f"final {curve[-1]['eval_acc']:.3f} after {am.epochs} epochs")
    print(f"model memory: {model.memory_kb:.1f} KB "
          f"(EM {enc.memory_bits // 8 // 1024} KB + "
          f"AM {am.am_memory_bits // 8 // 1024} KB)")

    cost = model.imc_cost(ImcArrayConfig())
    print(f"IMC deployment (128x128 arrays): "
          f"{cost.total_cycles} cycles/inference "
          f"({cost.em.cycles} EM + {cost.am.cycles} AM), "
          f"{cost.total_arrays} arrays, "
          f"AM utilization {cost.am.utilization:.0%}")
    # The AM search itself is ONE array pass: the paper's one-shot claim.
    assert cost.am.cycles == 1


if __name__ == "__main__":
    main()
