"""Quickstart: the full MEMHD pipeline (Fig. 2 of the paper) in ~40 lines.

Encode -> cluster-init (R=0.8, confusion-driven allocation) -> 1-bit
quantization -> quantization-aware iterative learning -> one-shot
associative search, plus the IMC deployment accounting for the trained
model.

  PYTHONPATH=src python examples/quickstart.py

Choosing a deployment backend
-----------------------------
One trained model maps onto every execution substrate through ONE
call: ``model.deploy(target=..., **backend_opts)`` dispatches through
the string-keyed backend registry (``repro.deploy``), and every
artifact it returns implements the same ``DeployedArtifact`` protocol
(``predict`` / ``predict_features`` / ``score`` / ``resident_bytes`` /
``imc_cost``), so serving code never branches on the substrate:

    packed = model.deploy(target="packed")     # 1-bit XOR+popcount
    floats = model.deploy(target="unpacked")   # float MXU (parity ref)
    analog = model.deploy(target="imc",        # simulated noisy device
                          sim=ImcSimConfig(adc_bits=6, noise_sigma=0.5))
    int4   = model.deploy(target="multibit",   # bit-sliced int4 cells
                          cell_bits=4)
    coarse = model.deploy(target="hierarchical")  # two-stage top-k index

* ``"packed"`` (the default) packs the trained binary AM 8 cells/byte
  into a (ceil(D/8), C) uint8 residence — the paper's Table-I 1-bit
  accounting made literal, 8x smaller than byte-per-cell storage (32x
  vs the float32 training copy) — and answers queries with the fused
  XOR+popcount kernel. It also serves raw features in ONE dispatch:
  ``predict_features`` chains the fused encode kernel (projection MVM
  + sign binarization + bitpack, accumulator in VMEM) straight into
  the packed search, so the float hypervector never touches HBM.
* ``"unpacked"`` keeps the ±1 float AM and the float ``am_search``
  kernel. Bit-exact with ``"packed"`` — the parity baseline.
* ``"imc"`` burns the AM onto a *simulated analog device*
  (``repro.imcsim``): seeded conductance noise / stuck-at faults in
  the resident cells, per-array analog partial sums through a
  finite-resolution ADC. An ideal ``sim`` is bit-exact with the
  digital backends; a lossy one is what the robustness sweeps measure.
* ``"multibit"`` stores the FLOAT shadow AM at 2-8 bits per cell as
  plane-packed offset codes and serves it through the bit-sliced
  Pallas kernel — the precision ladder between ``"packed"`` (1 bit)
  and ``"unpacked"`` (32 bits). See "Multi-bit cells" below.
* ``"hierarchical"`` builds the two-stage coarse-to-fine top-k index
  over the packed AM (see "Scaling to huge label spaces" below).

New backends (remote arrays, product-quantized residuals) plug in
with ``@repro.deploy.register_backend("name")`` — no model changes.

Serving at scale: any artifact wraps in
``repro.deploy.ShardedArtifact(dep, devices=N)``, which shards each
request batch over a data-parallel device mesh (AM replicated, rows
sharded) bit-exactly. The batched serving driver exposes all of it:

    python -m repro.launch.serve_memhd --smoke --target imc
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.launch.serve_memhd --smoke --fused --devices 8

(greedy request batching, double-buffered dispatch — the host pads
batch k+1 while batch k is in flight — and a latency/QPS JSON report
tagged with ``backend`` and ``devices``). The scaling sweep lives in
``python -m benchmarks.serve_scaling``; the kernel comparisons in
``benchmarks/packed_vs_unpacked.py`` and ``--only pipeline``.

Multi-bit cells: trading bits for accuracy
------------------------------------------
The 1-bit packed deployment throws away everything but the sign of the
trained float shadow. ``target="multibit"`` keeps 2-8 bits of it:
``quantize_am`` picks a symmetric mid-tread quantizer (the clip chosen
by an MSE grid search — a max-anchored scale at 2 bits rounds most of
the heavy-tailed shadow to zero), and the codes are packed as
``cell_bits`` bit PLANES of 8 cells/byte along D. The bit-sliced
kernel (``kernels/am_search_multibit``) runs one {0,1} MVM pass per
plane on the same ``am_search_imc`` tiling and combines the partial
sums with shifted weights in VMEM — integer-exact, so the kernel is
bit-for-bit the code-domain MVM (asserted against its oracle across
the parity grid). Residence is ``C*D*cell_bits/8`` bytes: 16x / 8x
under the float AM at 2 / 4 bits, and the Table-I ``memory_bits``
accounting generalizes via ``MemhdConfig.am_memory_bits_at(b)``.

Because deployment quantizes the float shadow, fine-tune the model
against the SAME quantized view before freezing it — the
quantization-aware hook re-quantizes the live shadow inside every
training-time similarity MVM (the §III-C idea at b bits):

    from repro.imcsim import multibit_finetune
    tuned, _ = multibit_finetune(model, key, x, y, cell_bits=4)
    dep = tuned.deploy(target="multibit", cell_bits=4)

An optional drift-only ``ImcSimConfig`` attaches array geometry and
per-tile readout offsets (storage perturbations are 1-bit semantics
and are rejected). The frontier bench sweeps bits in {1, 2, 4} and
gates iso-accuracy at >= 2x memory reduction vs the unpacked path:
``python -m benchmarks.run --only multibit_frontier``; serving rides
the standard driver via ``--target multibit --cell-bits 4``.

Scaling to huge label spaces
----------------------------
The flat packed scan is linear in the class count C — fine at the
paper's C = 128, a wall at 100k classes. ``target="hierarchical"``
deploys a two-stage coarse-to-fine index over the SAME trained AM:
offline, the centroids are k-means-clustered (k-means++ seeded,
capacity-balanced) into G ~ 1.4*sqrt(C) super-centroids and physically
permuted so each cluster occupies contiguous 128-column packed tiles;
online, a first Pallas pass
(``am_shortlist``) scores the query against the G packed
super-centroids and shortlists the S best clusters, and a second pass
(``am_search_sparse``) gathers only those clusters' tiles and runs the
packed scan with a fused streaming top-k epilogue — query cost
O(G + S * C/G) instead of O(C):

    dep = model.deploy(target="hierarchical")            # exact: S = G
    dep = model.deploy(target="hierarchical",
                       groups=448, shortlist=8)          # sublinear
    classes, ids, sims = dep.predict_topk(feats, k=5)    # fused top-k

The defaults are the DEGENERATE configuration S = G, which is
bit-exact with the flat packed scan (asserted in-bench and in tests) —
speed becomes opt-in by choosing S < G, trading recall@1 (>= 99% on
clustered label spaces at the benchmark's settings) for a >= 5x scan
reduction at C >= 32k (``python -m benchmarks.run --only
hierarchical_search`` sweeps C in {512, 4k, 32k, 100k} and asserts
both floors). Guidance: G ~ 1.4*sqrt(C) — the over-partitioning makes
k-means split natural clusters (benign) rather than merge them (a
recall hole no S can fix); raise S until recall@1 plateaus (8-16 is
the bench's sweet spot). Top-k serving rides the same driver flags and
report schema:

    python -m repro.launch.serve_memhd --smoke \\
        --target hierarchical --topk 5
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        python -m repro.launch.serve_memhd --smoke --devices 8 \\
            --target hierarchical --topk 5        # sharded, bit-exact

Online serving and live updates
-------------------------------
``repro.serve`` turns a deployed artifact into a *long-running* online
service. The ``OnlineEngine`` consumes a timed event stream (open-loop
Poisson arrivals with per-request deadline budgets) through a
deadline-aware adaptive batcher: requests wait in an admission queue
and a batch closes the moment it fills, the tightest admitted deadline
runs out of slack (against an EWMA service-time model per padded batch
bucket), or a bounded-staleness cap trips — so p99 stays under the
deadline while batches stay as large (cheap per row) as the budget
allows. The model KEEPS LEARNING while it serves: labeled ``Feedback``
events buffer into a ``StreamingUpdater``, and each fold runs the
device-resident QAIL scan over the buffer and re-freezes a NEW
artifact generation which the engine swaps in as an atomic reference
replacement — in-flight batches keep the old generation (the artifact
is an immutable jit *operand*, so the swap is race-free and bit-exact
by construction):

    from repro.serve import OnlineEngine, StreamingUpdater
    upd = StreamingUpdater(model, model.deploy(target="packed"))
    eng = OnlineEngine(upd, max_batch=128)
    report = eng.serve(events)        # arrivals + feedback, timed

Feedback labeled with a class the model has NEVER seen grows the AM
(D,C) -> (D,C+k) and re-packs the artifact through the deploy registry
— a model can go live on 9 classes and learn the 10th from production
traffic. Same-geometry folds are *shape-stable*: the swap hits the
warmed jit cache and ``report["recompiles_steady_state"]`` stays 0
(class growth re-warms the batch buckets once, inside an excluded
compile window — the report itemizes every compile by phase). Each
generation lands in the obs layer (``model_generation`` gauge,
``update_fold_ms`` histogram, one event per fold). The scenario driver
stages all of it — drift fold + live class append, any backend, any
device count:

    python -m repro.launch.serve_online --smoke --append-class
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        python -m repro.launch.serve_online --smoke --devices 8 \\
            --append-class --target hierarchical

and ``python -m benchmarks.run --only online_serving`` gates the p99
deadline floor, the zero-recompile swap, and the appended-class hit
rate.

Recovering accuracy on noisy devices
------------------------------------
The accuracy a lossy ``"imc"`` deployment costs is recoverable:
noise-aware QAIL
fine-tuning evaluates the training-time similarity MVM against the
very device instance the model will deploy onto (chip-in-the-loop —
the quantization-aware idea of §III-C taken down to the hardware), so
the centroids learn margins that survive the analog readout:

    from repro.imcsim import noise_aware_finetune
    tuned, _ = noise_aware_finetune(model, key, x, y, sim, epochs=10)
    tuned.deploy(target="imc", sim=sim).score(x, y)   # most of it back

The demo below measures the drop and the recovery; for the full
accuracy-vs-fidelity report see
``python -m repro.launch.robustness_report --smoke`` and the
``fig_robustness`` entry of ``python -m benchmarks.run``.

Training at scale
-----------------
``fit`` is a device-resident engine: the training set is encoded ONCE,
prebatched on device, and every epoch runs as a single compiled
``lax.scan`` (one dispatch, one host sync per epoch — measured >= 5x
the samples/sec of the old per-batch host loop; see
``python -m benchmarks.run --only train_throughput``). Three ways to
scale it up from the call below:

* **Checkpointed fit** — pass a manager and training auto-resumes
  bit-exactly from the newest valid checkpoint:

      from repro.checkpoint import CheckpointConfig, CheckpointManager
      ck = CheckpointManager(CheckpointConfig("/tmp/memhd_ck"))
      model, hist = model.fit(key, x, y, ckpt=ck, ckpt_every=5)

* **Data-parallel fit** — shard the batch over every device; per-shard
  Eq.-(6) deltas sync with one bf16 all-reduce per minibatch:

      model, hist = model.fit_sharded(key, x, y)   # mesh=all devices

* **The fault-tolerant driver** — MEMHD is a registered arch of the
  production train driver (atomic checkpoints, watchdog, auto-resume):

      PYTHONPATH=src python -m repro.launch.train --arch memhd \\
          --smoke --steps 20 --ckpt-dir /tmp/memhd_run

Tracking performance
--------------------
Every bench run persists its numbers — they no longer evaporate with
the terminal. ``python -m benchmarks.run --fast`` writes one
schema-versioned ``BENCH_<name>.json`` per bench (QPS, true-median /
min / p95 / p99 latencies, per-kernel microbench times, git SHA) into
``benchmarks/results/`` (override: ``--record-dir`` or
``$MEMHD_BENCH_DIR``); the serving driver joins the same trajectory
via ``python -m repro.launch.serve_memhd --smoke --record-dir ...``.
The regression gate diffs a fresh run against the committed baselines
in ``benchmarks/baselines/`` and exits non-zero on slowdowns or
silently-vanished metrics (CI runs it on every PR):

    python -m benchmarks.run --fast            # record a run
    python -m benchmarks.gate                  # diff vs baselines
    python -m benchmarks.gate --update-baselines   # promote a run

Selection is loud now: ``--only fig3`` prints what each token resolved
to, overrides ``--fast``, and exits non-zero when a token matches
nothing. The five hot-path kernels (``am_search_packed``,
``encode_pack``, ``qail_update``, ``am_shortlist``,
``am_search_sparse``) read their batch-tile height from a
committed autotune cache (searched over tilings under a VMEM budget,
every candidate bit-exact with its ``ref.py`` oracle); re-tune after
changing a kernel with:

    PYTHONPATH=src python -m repro.kernels.autotune --kernel all

Observing the pipeline
----------------------
Every driver shares one dependency-free observability layer
(``repro.obs``): a metrics registry (counters / gauges / log-bucket
histograms), nested wall-clock tracing spans, and JAX runtime
introspection (XLA compile counting via ``jax.monitoring``, device
memory gauges). The serving driver exports both surfaces:

    PYTHONPATH=src python -m repro.launch.serve_memhd --smoke \\
        --depth 4 --metrics-out metrics.json --trace-out trace.json

``metrics.json`` is the full registry snapshot; the serving report
itself gains a ``metrics`` section with the three numbers to check
first:

  * ``recompiles_steady_state`` — XLA compiles during the *timed*
    serve (after warmup). Anything above 0 means a shape leaked
    through padding and jit is re-tracing per batch: the recompile
    tax that hides inside "slow serving" numbers.
  * ``dispatch_tiers`` — per-kernel counts of which execution tier
    actually served each dispatch: ``pallas`` (the real kernel),
    ``xla-oracle`` (the bit-exact XLA fallback some kernels take
    off-TPU), ``ref`` (the pure-jnp oracle). A kernel you believed
    was on its fast path showing up under ``ref`` is a silent 10x.
  * ``compiles_total`` — compiles for the whole process (warmup
    included), for judging cold-start cost.

The report also splits every latency into ``queue_ms_*`` (time a
batch sat behind its predecessors in the device queue — backpressure)
vs ``service_ms_*`` (time the device actually worked); at
``--depth 1`` queue is identically zero, and the two always sum to
``lat_ms_*``. ``trace.json`` is Chrome trace-event format: open
https://ui.perfetto.dev and drop the file in to see the per-batch
``host_prep`` / ``pad`` / ``dispatch`` / ``device_wait`` spans and
exactly where the pipeline bubbles are. The same layer powers
``--log-json`` (structured logs) on every driver, per-epoch
``events.jsonl`` next to training checkpoints, and the dispatch-tier
regression check in ``benchmarks.gate`` (a kernel falling from
``pallas`` to ``ref`` fails CI even when timings sit inside noise).
"""
import jax

from repro.core import EncoderConfig, ImcSimConfig, MemhdConfig, MemhdModel
from repro.core.imc import ImcArrayConfig
from repro.data import load_dataset
from repro.imcsim import noise_aware_finetune


def main():
    ds = load_dataset("mnist", train_per_class=400, test_per_class=80)
    print(f"dataset: {ds.name} ({ds.source}), {ds.train_x.shape[0]} train")

    enc = EncoderConfig(kind="projection", features=ds.features, dim=128)
    am = MemhdConfig(dim=128, columns=128, classes=ds.classes,
                     init_ratio=0.8, epochs=20, lr=0.01)
    model = MemhdModel.create(jax.random.key(0), enc, am)

    model, hist = model.fit(jax.random.key(1), ds.train_x, ds.train_y,
                            eval_feats=ds.test_x, eval_labels=ds.test_y)
    curve = [r for r in hist["curve"] if "eval_acc" in r]
    print(f"init acc {curve[0]['eval_acc']:.3f} -> "
          f"final {curve[-1]['eval_acc']:.3f} after {am.epochs} epochs")
    print(f"model memory: {model.memory_kb:.1f} KB "
          f"(EM {enc.memory_bits // 8 // 1024} KB + "
          f"AM {am.am_memory_bits // 8 // 1024} KB)")

    cost = model.imc_cost(ImcArrayConfig())
    print(f"IMC deployment (128x128 arrays): "
          f"{cost.total_cycles} cycles/inference "
          f"({cost.em.cycles} EM + {cost.am.cycles} AM), "
          f"{cost.total_arrays} arrays, "
          f"AM utilization {cost.am.utilization:.0%}")
    # The AM search itself is ONE array pass: the paper's one-shot claim.
    assert cost.am.cycles == 1

    # 1-bit deployment: pack the AM 8 cells/byte and serve it through
    # the XOR+popcount kernel — same predictions, 8x smaller residence.
    deployed = model.deploy(target="packed")
    acc_packed = deployed.score(ds.test_x, ds.test_y)
    acc_float = model.score(ds.test_x, ds.test_y)
    assert acc_packed == acc_float
    assert acc_packed == model.deploy(target="unpacked").score(
        ds.test_x, ds.test_y)  # every digital backend agrees
    print(f"packed deployment: {deployed.resident_am_bytes} B resident "
          f"AM ({deployed.am_memory_ratio:.0f}x smaller than "
          f"byte-per-cell), acc {acc_packed:.3f} == float {acc_float:.3f}")

    # Serving raw features: the fused single-dispatch pipeline
    # (encode + sign + bitpack kernel chained into the packed search)
    # answers the same requests bit-exactly — no float H in HBM.
    import numpy as np
    pred_fused = np.asarray(deployed.predict_features(ds.test_x))
    pred_staged = np.asarray(deployed.predict(ds.test_x))
    assert (pred_fused == pred_staged).all()
    print(f"fused feature serving: {pred_fused.shape[0]} requests, "
          f"predictions bit-exact with the staged pipeline")

    # Coarse-to-fine deployment: at its exact defaults (S = G) the
    # hierarchical index reproduces the packed scan bit for bit, and
    # adds the fused top-k epilogue; at 100k classes S < G makes the
    # scan sublinear (see the docstring section above).
    hier = model.deploy(target="hierarchical")
    assert (np.asarray(hier.predict(ds.test_x)) == pred_staged).all()
    top5, _, _ = hier.predict_topk(ds.test_x[:256], 5)
    assert (np.asarray(top5)[:, 0] == pred_staged[:256]).all()
    print(f"hierarchical deployment ({hier.serving_mode}): bit-exact "
          f"with packed; top-5 classes served in one fused dispatch")

    # Multi-bit cells: keep 4 bits of the float shadow instead of its
    # sign. Quantization-aware fine-tuning trains against the same
    # 4-bit view the deployment serves; residence sits 8x under the
    # float AM (and the kernel readout is integer-exact vs its oracle).
    from repro.imcsim import multibit_finetune
    tuned4, _ = multibit_finetune(model, jax.random.key(3),
                                  ds.train_x, ds.train_y, cell_bits=4,
                                  epochs=4)
    int4 = tuned4.deploy(target="multibit", cell_bits=4)
    acc_int4 = int4.score(ds.test_x, ds.test_y)
    unpacked_bytes = model.deploy(
        target="unpacked").resident_am_bytes
    print(f"multibit deployment ({int4.serving_mode}): "
          f"{int4.resident_am_bytes} B resident "
          f"({unpacked_bytes / int4.resident_am_bytes:.1f}x under the "
          f"float AM), acc {acc_int4:.3f} vs packed {acc_packed:.3f}, "
          f"memory_bits {int4.memory_bits}")
    assert unpacked_bytes / int4.resident_am_bytes >= 2.0

    # Live updates: the deployment keeps learning while it serves.
    # Labeled feedback from a drifted distribution folds through the
    # QAIL scan into a NEW artifact generation — same geometry, so the
    # swap is shape-stable (zero recompiles) — and recovers the
    # accuracy the drift cost.
    from repro.serve import StreamingUpdater, apply_drift
    drifted_x = apply_drift(np.asarray(ds.test_x), 0.4)
    acc_drift = float(np.mean(
        np.asarray(deployed.predict(drifted_x)) == np.asarray(ds.test_y)))
    upd = StreamingUpdater(model, deployed, fold_epochs=2)
    upd.ingest(apply_drift(np.asarray(ds.train_x), 0.4), ds.train_y)
    gen1 = upd.fold()
    acc_recovered = float(np.mean(
        np.asarray(upd.artifact.predict(drifted_x))
        == np.asarray(ds.test_y)))
    assert gen1.shape_stable  # same (D, C): the swap recompiles nothing
    print(f"online fold (generation {gen1.generation}, "
          f"{gen1.fold_ms:.0f} ms): drifted acc {acc_drift:.3f} -> "
          f"{acc_recovered:.3f}, swap shape-stable")

    # Deploying to noisy IMC arrays: an ideal simulated device is
    # bit-exact with the digital path...
    acc_ideal = model.deploy(target="imc",
                             sim=ImcSimConfig()).score(ds.test_x,
                                                       ds.test_y)
    assert acc_ideal == acc_float
    # ...a lossy one is not; noise-aware (chip-in-the-loop) QAIL
    # fine-tuning recovers most of the drop on that same device.
    sim = ImcSimConfig(adc_bits=8, noise_sigma=0.5, seed=7)
    acc_noisy = model.deploy(target="imc", sim=sim).score(ds.test_x,
                                                          ds.test_y)
    tuned, _ = noise_aware_finetune(model, jax.random.key(2),
                                    ds.train_x, ds.train_y, sim,
                                    epochs=8)
    acc_tuned = tuned.deploy(target="imc", sim=sim).score(ds.test_x,
                                                          ds.test_y)
    print(f"imc deployment (8-bit ADC, sigma=0.5): {acc_float:.3f} "
          f"digital -> {acc_noisy:.3f} noisy -> {acc_tuned:.3f} after "
          f"noise-aware QAIL")


if __name__ == "__main__":
    main()
