"""End-to-end LM training driver (deliverable (b): ~100M for a few
hundred steps).

Trains the *full* mamba2-130m config (or any --arch, or a --preset small
model for quick CPU runs) on the synthetic Zipf+motif stream with the
production substrate: AdamW + cosine schedule, atomic checkpoints,
auto-resume, watchdog. Loss dropping over a few hundred steps is the
acceptance signal (recorded in EXPERIMENTS.md).

  PYTHONPATH=src python examples/train_lm.py --preset small --steps 300
  PYTHONPATH=src python examples/train_lm.py --arch mamba2-130m --steps 200
"""
import argparse
import logging

from repro.launch.train import TrainRunConfig, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--preset", choices=["full", "small", "smoke"],
                    default="small")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(message)s")

    cfg = TrainRunConfig(
        arch=args.arch,
        smoke=args.preset in ("small", "smoke"),
        steps=args.steps,
        seq_len=args.seq_len,
        global_batch=args.batch,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=max(20, args.steps // 5),
    )
    out = run(cfg)
    drop = (out["first_loss"] or 0) - (out["last_loss"] or 0)
    print(f"\nloss {out['first_loss']:.3f} -> {out['last_loss']:.3f} "
          f"(drop {drop:+.3f}) over {out['steps_run']} steps")
    assert drop > 0, "loss did not decrease"


if __name__ == "__main__":
    main()
